"""Two-level (edge → server) ERA aggregation (`core.hierarchy`).

At fleet scale the K client uploads do not land on one box: edge
aggregators each reduce a contiguous shard of the cohort's (K, n, C)
probability stack to a single weighted partial sum, and the server adds
the ``n_edges`` partials and sharpens the result — the wire between edges
and server carries ``n_edges`` (n, C) tensors instead of K of them.

Parity contract (pinned by ``tests/test_cohort.py``):

* Weights are normalized **globally first** (`aggregation._normalize_weights`,
  whose total is the dot-lowered `losses.pinned_sum` — see that module's
  associativity note), so every edge scales its lanes by exactly the
  coefficients the flat einsum would use.  With ``n_edges=1`` the single
  "edge" computes the identical ``einsum("k,k...->...")`` over the identical
  operands, and the result is **bitwise** equal to `aggregation.weighted_sa`
  / `weighted_era` — the flat path is literally a special case.
* With ``n_edges >= 2`` the cross-client reduction is re-associated: the
  flat einsum accumulates all K lanes in one contraction, while the tree
  sums per-shard partials.  Floating-point addition is not associative, so
  bitwise parity is *not* promised — the contract degrades to a pinned
  tolerance (~1e-6 relative for f32 probability stacks; each extra tree
  level can add one more rounding of order eps * ||mean||).  What **is**
  exact at any depth: zero-weight lanes still contribute exactly nothing
  (0.0 * x == 0.0 inside whichever shard they fall), so the participation
  masking / sparse-plane guarantees survive hierarchy unchanged.

``use_kernel=True`` routes each edge's partial through the fused Pallas
weighted-mean kernel (`kernels.ops.weighted_mean`) for (K, N, C) stacks —
the per-shard reduce is exactly the flat kernel's job on a smaller K.  The
server stage (add ``n_edges`` partials, sharpen) is O(n_edges * n * C) and
stays in plain jnp.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .aggregation import _kernel_eligible, _normalize_weights

F32 = jnp.float32


def edge_shards(K: int, n_edges: int) -> list[tuple[int, int]]:
    """Contiguous ``[start, end)`` client shards, one per edge aggregator.
    Sizes differ by at most one; every client belongs to exactly one edge."""
    if not 1 <= n_edges <= K:
        raise ValueError(f"n_edges {n_edges} not in [1, {K}]")
    base, extra = divmod(K, n_edges)
    bounds, start = [], 0
    for e in range(n_edges):
        end = start + base + (1 if e < extra else 0)
        bounds.append((start, end))
        start = end
    return bounds


def hierarchical_weighted_sa(local_probs: jax.Array, weights: jax.Array,
                             n_edges: int = 1, use_kernel: bool = False,
                             interpret: bool | None = None) -> jax.Array:
    """Edge-sharded weighted mean: globally-normalized weights, per-edge
    partial sums, server adds the partials in edge order.  ``n_edges=1`` is
    bitwise `aggregation.weighted_sa`; deeper trees carry the tolerance
    contract documented in the module docstring."""
    w = _normalize_weights(weights)
    probs = local_probs.astype(F32)
    if n_edges == 1:
        # the flat path, verbatim (kernel route included) — bitwise anchor
        if use_kernel and _kernel_eligible(probs):
            from repro.kernels import ops as kops
            return kops.weighted_mean(probs, w, interpret=interpret)
        return jnp.einsum("k,k...->...", w, probs)
    partials = []
    for start, end in edge_shards(probs.shape[0], n_edges):
        if use_kernel and _kernel_eligible(probs):
            from repro.kernels import ops as kops
            partials.append(kops.weighted_mean(probs[start:end],
                                               w[start:end],
                                               interpret=interpret))
        else:
            partials.append(jnp.einsum("k,k...->...", w[start:end],
                                       probs[start:end]))
    # server stage: fixed left-to-right edge order, so the tree's rounding
    # is at least deterministic across runs of the same topology
    total = partials[0]
    for p in partials[1:]:
        total = total + p
    return total


def hierarchical_weighted_era(local_probs: jax.Array, weights: jax.Array,
                              temperature: float = 0.1, n_edges: int = 1,
                              use_kernel: bool = False,
                              interpret: bool | None = None) -> jax.Array:
    """Two-level ERA (Eq. 13 over an edge tree): edges reduce their shards,
    the server adds the partials and sharpens.  Note the kernel route here
    fuses *per edge* (weighted mean in VMEM) and sharpens at the server —
    unlike flat `weighted_era`'s single fused mean+sharpen kernel, the
    sharpen cannot live on an edge, since softmax of a partial sum is not a
    partial softmax."""
    mean = hierarchical_weighted_sa(local_probs, weights, n_edges,
                                    use_kernel, interpret)
    return jax.nn.softmax(mean / temperature, axis=-1)
