"""Benchmark 1: Federated Averaging (McMahan et al., AISTATS'17; paper §2.1).

One round: broadcast w0 -> E local epochs per client -> size-weighted
parameter average (Eq. 3).  BatchNorm running statistics are averaged like
any other leaf (standard FedAvg behaviour)."""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .client import LocalSpec, local_update
from .losses import pinned_sum


def weighted_average(stacked, weights: jax.Array):
    """Eq. 3: sum_k (I_k / I) w_k over the leading client axis.  The weight
    total is dot-lowered (`losses.pinned_sum`) so the normalization — and
    with it the whole average — is bitwise identical between the dense
    masked and participation-sparse round programs."""
    w = weights.astype(jnp.float32)
    w = w / pinned_sum(w)

    def avg(leaf):
        return jnp.einsum("k,k...->...", w, leaf.astype(jnp.float32)
                          ).astype(leaf.dtype)

    return jax.tree.map(avg, stacked)


def make_fedavg_round(spec: LocalSpec):
    """Returns a jitted round: (w0, s0, data, weights, rng) -> (w0', s0', loss).
    Malicious clients (model poisoning) are injected by the caller via the
    ``override`` hook on the stacked client params.

    .. deprecated:: prefer ``algorithms.FedAvgAlgorithm`` under
       ``engine.FedEngine`` (same math, unified API)."""

    def round_fn(w0, s0, x, y, weights, rng, override=None):
        K = x.shape[0]
        rngs = jax.random.split(rng, K)

        def per_client(xk, yk, rk):
            opt_state = spec.opt.init(w0)
            return local_update(spec, w0, s0, opt_state, xk, yk, rk)[:2]

        wk, sk = jax.vmap(per_client)(x, y, rngs)
        if override is not None:                     # (mask (K,), params (K,...))
            mask, forced = override
            pick = lambda a, b: jnp.where(
                mask.reshape((K,) + (1,) * (a.ndim - 1)), b.astype(a.dtype), a)
            wk = jax.tree.map(pick, wk, forced)
        new_w0 = weighted_average(wk, weights)
        new_s0 = weighted_average(sk, weights)
        return new_w0, new_s0

    return round_fn


def make_fedavg_engine(spec: LocalSpec, eval_fn: Callable):
    round_fn = jax.jit(make_fedavg_round(spec), static_argnames=())

    def run(w0, s0, x, y, weights, rounds: int, rng, log_every: int = 1,
            history=None):
        history = history if history is not None else []
        for r in range(rounds):
            rng, rk = jax.random.split(rng)
            w0, s0 = round_fn(w0, s0, x, y, weights, rk)
            if (r + 1) % log_every == 0:
                history.append({"round": r + 1, **eval_fn(w0, s0)})
        return w0, s0, history

    return run
