"""O(m) access to rows of ``jax.random.split(key, K)`` without the (K,) split.

The cohort-resident round plane (`core.cohort`, `sim.runner.CohortRunner`)
keeps only the sampled m clients resident, but the house RNG discipline
derives client k's per-round key as row k of ``jax.random.split(r, K)`` —
an O(K) array the million-client path must never materialize.

Under JAX's default threefry PRNG the split *is* a counter-mode block
cipher: ``split(key, K)`` encrypts the counters ``iota(2K)`` and reshapes
the flat 2K-word ciphertext to (K, 2).  The threefry primitive consumes a
flat even-length count array as two halves — element ``e`` of the flat
output is word 0 of the encrypted counter pair ``(e, K + e)`` when
``e < K`` and word 1 of the pair ``(e - K, e)`` otherwise — so any row k
of the split is two cipher words computable from the counter values
``2k`` and ``2k + 1`` alone.  `split_take` batches that: m rows cost one
threefry call over 4m counters, independent of K, and the result is
**bitwise** the corresponding rows of the dense split (pinned by
``tests/test_cohort.py`` across odd/even K and hypothesis-drawn ids).

Anything that is not a raw threefry key (typed keys of another impl, a
non-default global impl) falls back to the dense
``jnp.take(jax.random.split(key, K), ids, axis=0)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _is_raw_threefry(key) -> bool:
    """Raw uint32 (2,) keys are threefry keys by construction (the repo's
    ``jax.random.PRNGKey`` discipline); typed keys carry their impl."""
    if jnp.issubdtype(jnp.result_type(key), jax.dtypes.prng_key):
        return False
    return key.shape == (2,) and key.dtype == jnp.uint32


def split_rows(key, ids, num: int):
    """Rows ``ids`` of ``jax.random.split(key, num)``, computed in O(|ids|).

    ``ids``: (m,) integer client ids in ``[0, num)`` (traced or concrete);
    returns (m, 2) uint32 raw keys, bitwise equal to
    ``jnp.take(jax.random.split(key, num), ids, axis=0)``.
    """
    if not _is_raw_threefry(key):
        # mode="clip": typed key dtypes reject jnp.take's default fill mode
        return jnp.take(jax.random.split(key, num), jnp.asarray(ids), axis=0,
                        mode="clip")
    from jax.extend.random import threefry_2x32
    ids = jnp.asarray(ids).astype(jnp.uint32)
    num = jnp.uint32(num)
    # flat ciphertext elements (2k, 2k+1) form row k of the (num, 2) split
    e = jnp.stack([2 * ids, 2 * ids + 1], axis=-1).reshape(-1)      # (2m,)
    lo = jnp.where(e < num, e, e - num)
    # counts = [lo | lo+num]: the primitive encrypts halves pairwise, so
    # out[:2m] are the pairs' first words and out[2m:] their second words
    out = threefry_2x32(key, jnp.concatenate([lo, lo + num]))
    words = jnp.where(e < num, out[: e.shape[0]], out[e.shape[0]:])
    return words.reshape(ids.shape[0], 2)


# the name the algorithms use: "take rows of split(key, num)"
split_take = split_rows
