# The paper's primary contribution: the DS-FL protocol (Algorithm 1), its
# ERA aggregation operator, the FedAvg/FD benchmarks, attack models and
# communication accounting.
from . import aggregation, attacks, client, comm, fd, fedavg, llm_dsfl, \
    losses, protocol  # noqa
