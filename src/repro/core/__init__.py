# The paper's primary contribution: the DS-FL protocol (Algorithm 1), its
# ERA aggregation operator, the FedAvg/FD benchmarks, attack models and
# communication accounting.  `algorithms` + `engine` + `wire` form the
# unified FedAlgorithm API; `protocol.DSFLEngine` et al. are kept as
# deprecated reference implementations.
from . import aggregation, algorithms, attacks, client, comm, engine, fd, \
    fedavg, llm_algorithms, llm_dsfl, losses, protocol, wire  # noqa
