"""Wire-format codec layer: what actually crosses the network each round.

`comm.CommModel` computes the paper's Table 1/2 byte counts analytically;
this module makes them *measured*.  A `Codec` turns an upload payload (any
pytree of arrays — per-sample logits for DS-FL, a per-class logit table for
FD, the full parameter pytree for FedAvg) into its on-the-wire encoding,
and `payload_bytes` sums the encoded leaves' true byte sizes.  Tests assert
``payload_bytes(encode(payload)) * (K + 1) == CommModel.round_bytes(...)``
so the reproduction's communication claim is checked against real tensors,
not just arithmetic.

Codecs are shape-polymorphic and traceable, so sizes can be measured for
free with ``jax.eval_shape`` (see `measured_payload_bytes`) — no FLOPs, no
device transfers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .aggregation import topk_compress, topk_decompress

F32 = jnp.float32


def nbytes(tree) -> int:
    """Total bytes of a pytree of arrays (or ShapeDtypeStructs)."""
    return sum(math.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree.leaves(tree))


@dataclass(frozen=True)
class Codec:
    """Base codec: identity framing of float32 leaves ("dense-f32")."""
    name: str = "dense_f32"

    def encode(self, payload):
        return jax.tree.map(lambda a: a.astype(F32), payload)

    def decode(self, encoded):
        return jax.tree.map(lambda a: a.astype(F32), encoded)

    def payload_bytes(self, encoded) -> int:
        return nbytes(encoded)


@dataclass(frozen=True)
class DenseF32Codec(Codec):
    name: str = "dense_f32"


@dataclass(frozen=True)
class FP16Codec(Codec):
    """Half-precision exchange: 2 bytes per logit, decoded back to f32."""
    name: str = "fp16"

    def encode(self, payload):
        return jax.tree.map(lambda a: a.astype(jnp.float16), payload)


@dataclass(frozen=True)
class TopKCodec(Codec):
    """Top-k sparsified exchange over the class axis (beyond paper): each
    leaf (..., C) becomes renormalized ``{"v": (..., k) f32, "i": (..., k)
    i32}`` — k*(4+4) bytes/sample instead of C*4.  ``n_classes`` is needed
    to densify on decode."""
    name: str = "topk"
    k: int = 32
    n_classes: int = 10

    def encode(self, payload):
        def enc(a):
            v, i = topk_compress(a.astype(F32), self.k)
            return {"v": v, "i": i}
        return jax.tree.map(enc, payload)

    def decode(self, encoded):
        return jax.tree.map(
            lambda d: topk_decompress(d["v"], d["i"], self.n_classes),
            encoded, is_leaf=lambda d: isinstance(d, dict) and "v" in d)


CODECS = {"dense_f32": DenseF32Codec, "fp16": FP16Codec, "topk": TopKCodec}


def make_codec(name: str, **kw) -> Codec:
    return CODECS[name](**kw)


def measured_payload_bytes(codec: Codec, payload_fn, *args) -> int:
    """Bytes of ``codec.encode(payload_fn(*args))`` measured on the actual
    encoded pytree via ``jax.eval_shape`` (shapes/dtypes only — free)."""
    enc = jax.eval_shape(lambda *a: codec.encode(payload_fn(*a)), *args)
    return nbytes(enc)
