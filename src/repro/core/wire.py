"""Wire-format codec layer: what actually crosses the network each round.

`comm.CommModel` computes the paper's Table 1/2 byte counts analytically;
this module makes them *measured*.  A `Codec` turns an upload payload (any
pytree of arrays — per-sample logits for DS-FL, a per-class logit table for
FD, the full parameter pytree for FedAvg) into its on-the-wire encoding,
and `payload_bytes` sums the encoded leaves' true byte sizes.  Tests assert
``payload_bytes(encode(payload)) * (K + 1) == CommModel.round_bytes(...)``
so the reproduction's communication claim is checked against real tensors,
not just arithmetic.

Codecs are shape-polymorphic and traceable, so sizes can be measured for
free with ``jax.eval_shape`` (see `measured_payload_bytes`) — no FLOPs, no
device transfers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .aggregation import topk_compress, topk_decompress

F32 = jnp.float32


def nbytes(tree) -> int:
    """Total bytes of a pytree of arrays (or ShapeDtypeStructs)."""
    return sum(math.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree.leaves(tree))


@dataclass(frozen=True)
class Codec:
    """Base codec: identity framing of float32 leaves ("dense-f32").

    ``encode_up``/``encode_down`` are the per-leg encodings (client upload
    vs. server multicast broadcast); symmetric codecs alias both to
    ``encode``, while `AsymmetricCodec` pays each leg differently — the
    `repro.sim` virtual clock charges uplink and downlink from these."""
    name: str = "dense_f32"

    def encode(self, payload):
        return jax.tree.map(lambda a: a.astype(F32), payload)

    def decode(self, encoded):
        return jax.tree.map(lambda a: a.astype(F32), encoded)

    def encode_up(self, payload):
        return self.encode(payload)

    def encode_down(self, payload):
        return self.encode(payload)

    def decode_up(self, encoded):
        return self.decode(encoded)

    def decode_down(self, encoded):
        return self.decode(encoded)

    def payload_bytes(self, encoded) -> int:
        return nbytes(encoded)


@dataclass(frozen=True)
class DenseF32Codec(Codec):
    name: str = "dense_f32"


@dataclass(frozen=True)
class FP16Codec(Codec):
    """Half-precision exchange: 2 bytes per logit, decoded back to f32."""
    name: str = "fp16"

    def encode(self, payload):
        return jax.tree.map(lambda a: a.astype(jnp.float16), payload)


@dataclass(frozen=True)
class TopKCodec(Codec):
    """Top-k sparsified exchange over the class axis (beyond paper): each
    leaf (..., C) becomes renormalized ``{"v": (..., k) f32, "i": (..., k)
    i32}`` — k*(4+4) bytes/sample instead of C*4.  ``n_classes`` is needed
    to densify on decode."""
    name: str = "topk"
    k: int = 32
    n_classes: int = 10

    def encode(self, payload):
        def enc(a):
            v, i = topk_compress(a.astype(F32), self.k)
            return {"v": v, "i": i}
        return jax.tree.map(enc, payload)

    def decode(self, encoded):
        return jax.tree.map(
            lambda d: topk_decompress(d["v"], d["i"], self.n_classes),
            encoded, is_leaf=lambda d: isinstance(d, dict) and "v" in d)


@dataclass(frozen=True)
class Int8Codec(Codec):
    """Per-tensor affine int8 quantization: each leaf (any shape) becomes
    ``{"q": uint8, "scale": f32 scalar, "zero": f32 scalar}`` — 1 byte per
    logit plus an 8-byte per-tensor (scale, zero) sidecar.  Decode is
    ``q * scale + zero``; the roundtrip error is bounded by ``scale / 2``
    with ``scale = (max - min) / 255`` (see tests/test_wire_props.py)."""
    name: str = "int8"

    def encode(self, payload):
        def enc(a):
            a = a.astype(F32)
            lo, hi = jnp.min(a), jnp.max(a)
            scale = jnp.maximum(hi - lo, 1e-12) / 255.0
            q = jnp.clip(jnp.round((a - lo) / scale), 0, 255).astype(jnp.uint8)
            return {"q": q, "scale": scale.astype(F32), "zero": lo.astype(F32)}
        return jax.tree.map(enc, payload)

    def decode(self, encoded):
        return jax.tree.map(
            lambda d: d["q"].astype(F32) * d["scale"] + d["zero"],
            encoded, is_leaf=lambda d: isinstance(d, dict) and "q" in d)


@dataclass(frozen=True)
class AsymmetricCodec(Codec):
    """Per-leg codec (cf. arXiv:2409.17517 hybrid exchanges): a sparse/cheap
    uplink from each client and a dense broadcast downlink — by default top-k
    (value, index) pairs up, dense fp16 down.  ``encode``/``decode`` alias
    the uplink leg (the payload `FedEngine.measured_round_bytes` multiplies
    by K); the sim clock charges each leg separately via
    ``measured_leg_bytes``."""
    name: str = "asym"
    up: Codec = field(default_factory=TopKCodec)
    down: Codec = field(default_factory=FP16Codec)

    def encode(self, payload):
        return self.up.encode(payload)

    def decode(self, encoded):
        return self.up.decode(encoded)

    def encode_up(self, payload):
        return self.up.encode(payload)

    def encode_down(self, payload):
        return self.down.encode(payload)

    def decode_up(self, encoded):
        return self.up.decode(encoded)

    def decode_down(self, encoded):
        return self.down.decode(encoded)


CODECS = {"dense_f32": DenseF32Codec, "fp16": FP16Codec, "topk": TopKCodec,
          "int8": Int8Codec, "asym": AsymmetricCodec}


def make_codec(name: str, **kw) -> Codec:
    return CODECS[name](**kw)


def measured_payload_bytes(codec: Codec, payload_fn, *args) -> int:
    """Bytes of ``codec.encode(payload_fn(*args))`` measured on the actual
    encoded pytree via ``jax.eval_shape`` (shapes/dtypes only — free)."""
    enc = jax.eval_shape(lambda *a: codec.encode(payload_fn(*a)), *args)
    return nbytes(enc)
