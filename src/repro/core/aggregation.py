"""Logit aggregation operators (paper Section 3).

Clients upload per-sample probability vectors over the open-batch.  The server
aggregates them into the global logit:

  * SA  (Eq. 16): simple average.
  * ERA (Eq. 13): softmax(average / T) with T << 1 (paper: T = 0.1) —
    intentionally reduces entropy of the ambiguous non-IID average.
  * weighted ERA: reliability-weighted average (paper §5 "future work",
    implemented here as an extension).
  * top-k sparsified exchange: beyond-paper communication optimization for
    large-vocab models; ERA is applied after densifying the mean.

The fused mean+sharpen Pallas kernel lives in ``repro.kernels.era_sharpen``;
``era(..., use_kernel=True)`` routes through it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .losses import pinned_sum

F32 = jnp.float32


def sa(local_probs: jax.Array) -> jax.Array:
    """local_probs: (K, ..., C) -> (..., C).  Simple aggregation (Eq. 16)."""
    return jnp.mean(local_probs.astype(F32), axis=0)


def era(local_probs: jax.Array, temperature: float = 0.1,
        use_kernel: bool = False,
        interpret: bool | None = None) -> jax.Array:
    """Entropy-reduction aggregation (Eq. 13): sharpen the mean.

    ``use_kernel=True`` routes through the fused Pallas mean+softmax kernel;
    ``interpret=None`` auto-selects interpret mode on CPU only, so the kernel
    path actually compiles on TPU/GPU instead of silently interpreting."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.era_sharpen(local_probs, temperature, interpret=interpret)
    mean = sa(local_probs)
    return jax.nn.softmax(mean / temperature, axis=-1)


def _normalize_weights(weights: jax.Array) -> jax.Array:
    """(K,) nonneg -> normalized; an all-zero vector falls back to uniform
    explicitly instead of silently producing a zero mean.  The total is a
    dot-lowered sum (`losses.pinned_sum`) so the normalization is bitwise
    identical between the dense masked and participation-sparse round
    programs (a plain fused reduce may reassociate per-program)."""
    w = weights.astype(F32)
    total = pinned_sum(w)
    uniform = jnp.full_like(w, 1.0 / w.shape[0])
    return jnp.where(total > 0, w / jnp.maximum(total, 1e-9), uniform)


def _kernel_eligible(local_probs: jax.Array) -> bool:
    """The fused weighted kernel handles the (K, N, C) classification shape;
    higher-rank stacks (the LLM's (K, n, S, V)) keep the einsum path."""
    return local_probs.ndim == 3


def weighted_sa(local_probs: jax.Array, weights: jax.Array,
                use_kernel: bool = False,
                interpret: bool | None = None) -> jax.Array:
    """Weighted simple aggregation: the SA mean restricted to (or biased
    toward) the clients with nonzero weight.  Absent clients (weight 0)
    contribute exactly nothing — `sum(0 * p) == sum()` bitwise for the
    finite probability tensors crossing the wire.  The participation-sparse
    round plane (`algorithms.active_indices`/`scatter_zeros`) rides on this
    guarantee: it never computes absent clients' uploads at all and hands
    this function exact zeros in their lanes instead, which multiply to the
    same exact 0.0 the dense masked stack's lanes do.  ``use_kernel=True``
    routes (K, N, C) stacks through the fused Pallas weighted-mean kernel
    (one VMEM pass, no HBM round-trip for the intermediate)."""
    w = _normalize_weights(weights)
    if use_kernel and _kernel_eligible(local_probs):
        from repro.kernels import ops as kops
        return kops.weighted_mean(local_probs, w, interpret=interpret)
    return jnp.einsum("k,k...->...", w, local_probs.astype(F32))


def weighted_era(local_probs: jax.Array, weights: jax.Array,
                 temperature: float = 0.1, use_kernel: bool = False,
                 interpret: bool | None = None) -> jax.Array:
    """Reliability-weighted ERA. weights: (K,) nonneg, normalized here.
    An all-zero weight vector falls back to uniform weights explicitly
    (== plain ERA) instead of silently sharpening a zero mean.
    ``use_kernel=True`` fuses weighted mean + sharpen into one VMEM pass
    (`kernels.era_sharpen.weighted_era_sharpen_pallas`) instead of the
    two-pass einsum + softmax."""
    if use_kernel and _kernel_eligible(local_probs):
        from repro.kernels import ops as kops
        return kops.weighted_era_sharpen(
            local_probs, _normalize_weights(weights), temperature,
            interpret=interpret)
    mean = weighted_sa(local_probs, weights)
    return jax.nn.softmax(mean / temperature, axis=-1)


def participation_weights(mask: jax.Array, staleness: jax.Array | None = None,
                          decay: float = 1.0,
                          base: jax.Array | None = None) -> jax.Array:
    """Per-client aggregation weights for a partial-participation round.

    mask: (K,) 0/1 participation (absent clients get exactly zero weight);
    staleness: (K,) rounds since each participant last synced its global
    labels — decayed as ``decay**staleness`` (FedAsync-style staleness
    discount); base: (K,) reliability/base weights to modulate.  Fully
    vectorized (no per-client Python loop), jit/mesh-compatible; feed the
    result to ``weighted_era``/``weighted_sa``/``weighted_average``.

    If every participant decays/modulates to exactly zero (e.g.
    ``decay=0`` with an all-stale cohort), the result falls back to the
    raw mask — uniform over participants, still zero for absent clients —
    so a downstream normalizing average never divides by a zero total."""
    w = mask.astype(F32)
    if base is not None:
        w = w * base.astype(F32)
    if staleness is not None:
        w = w * jnp.power(jnp.asarray(decay, F32), staleness.astype(F32))
    return jnp.where(jnp.sum(w) > 0, w, mask.astype(F32))


def aggregate(local_probs: jax.Array, method: str = "era",
              temperature: float = 0.1, weights=None,
              use_kernel: bool = False,
              interpret: bool | None = None) -> jax.Array:
    """Dispatch on the paper's aggregation methods.  Whenever ``weights`` is
    given, ``use_kernel=True`` routes through the fused *weighted* Pallas
    kernel (weighted mean + optional sharpen in one VMEM pass) — the
    partial-participation/sim path no longer falls back to einsum+softmax."""
    if method == "sa":
        if weights is not None:
            return weighted_sa(local_probs, weights, use_kernel, interpret)
        return sa(local_probs)
    if method == "era":
        if weights is not None:
            return weighted_era(local_probs, weights, temperature,
                                use_kernel, interpret)
        return era(local_probs, temperature, use_kernel, interpret)
    if method == "weighted_era":
        assert weights is not None
        return weighted_era(local_probs, weights, temperature,
                            use_kernel, interpret)
    raise ValueError(method)


# -------------------------- top-k sparsified exchange (beyond paper) ---------
def topk_compress(probs: jax.Array, k: int):
    """probs: (..., C) -> (values (..., k), indices (..., k)) renormalized.
    The upload payload is k*(4+4) bytes/sample instead of C*4."""
    v, i = jax.lax.top_k(probs, k)
    v = v / jnp.maximum(jnp.sum(v, axis=-1, keepdims=True), 1e-9)
    return v.astype(F32), i.astype(jnp.int32)


def topk_decompress(values: jax.Array, indices: jax.Array, C: int) -> jax.Array:
    """Densify a sparsified distribution back to (..., C)."""
    out = jnp.zeros(values.shape[:-1] + (C,), F32)
    return jnp.put_along_axis(out, indices.astype(jnp.int32),
                              values.astype(F32), axis=-1, inplace=False)


def era_topk(local_values: jax.Array, local_indices: jax.Array, C: int,
             temperature: float = 0.1, k_out: int | None = None):
    """Aggregate sparsified client uploads: fused scatter-accumulate mean ->
    sharpen.  Optionally re-sparsify the global logit for the broadcast leg.

    The K client uploads — ``local_values``/``local_indices`` of shape
    (K, ..., k) over a C-way class axis — are scatter-added straight into
    one (..., C) accumulator, so the mean costs O(N·C + K·N·k) memory
    instead of materializing all K densified (..., C) copies (the old
    ``vmap(topk_decompress)`` path was O(K·N·C) — prohibitive for
    large-vocab LLM exchanges).  Equivalence with the dense path is pinned
    in tests/test_aggregation.py."""
    K = local_values.shape[0]
    kk = local_values.shape[-1]
    inner = local_values.shape[1:-1]               # row dims, e.g. (N,) / (n, S)
    n = 1
    for d in inner:
        n *= d
    # fold the client axis into the per-row slot axis: each of the n rows
    # scatter-accumulates its K*k (index, value) pairs in one segment-sum
    val = jnp.moveaxis(local_values.astype(F32), 0, -2).reshape(n, K * kk)
    idx = jnp.moveaxis(local_indices.astype(jnp.int32), 0, -2).reshape(n, K * kk)
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    mean = (jnp.zeros((n, C), F32).at[rows, idx].add(val) / K).reshape(
        inner + (C,))
    g = jax.nn.softmax(mean / temperature, axis=-1)
    if k_out is not None:
        return topk_compress(g, k_out)
    return g
