"""Host-side client-state store + slab planning for cohort-resident rounds.

The cohort-resident round plane (`BatchCtx.cohort`) keeps only the sampled
clients on device: a `ClientStore` holds every *previously touched* client's
state host-side, keyed by global client id, and hands the engine an (S, ...)
slab at chunk entry / absorbs it back at chunk exit.  Clients that have
never participated are **lazily initialized** on first gather via
``init_fn(ids)`` — which, because per-client init keys are a function of the
global id alone (`core.prng.split_take`), produces bitwise the rows a dense
up-front ``init`` would have (pinned by ``tests/test_cohort.py``).  Resident
memory is therefore O(#touched clients) on the host and O(S) on device,
independent of the fleet size K.

Slab layout (`build_slab` / `slab_ctx_plan`): one fixed-size slab serves a
whole ``chunk_rounds`` fused scan — the sorted ascending union of the
chunk's cohort ids, padded to the static size S with duplicates of the
first id.  Pad lanes carry mask 0 in every round and are dropped before
write-back, so they can never clobber a real client's stored state; fixing
S across chunks keeps the engine's treedef/shape-keyed jit caches warm.
Sorted-ascending real lanes also preserve the dense round's relative lane
order, which is what lets the slab's cross-client reductions (all
dot-lowered via `losses.pinned_sum` or exact-zero-lane einsums) reproduce
the dense masked round bit-for-bit at small K.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import numpy as np

from ..checkpoint import load_pytree, save_pytree
from ..obs import trace as obs


class ClientStore:
    """Host-side id -> client-state rows, with lazy per-id initialization.

    ``init_fn(ids)`` builds a fresh stacked slab for (m,) global ids — e.g.
    ``lambda ids: algo.init_cohort(rng0, model_init, ids, K)``.  Rows are
    stored as NumPy leaves (host RAM); `gather` returns stacked NumPy
    leaves ready to cross into jit.
    """

    def __init__(self, init_fn: Callable):
        self.init_fn = init_fn
        self._rows: dict[int, list] = {}
        self._treedef = None

    def __len__(self) -> int:
        return len(self._rows)

    def ids(self) -> np.ndarray:
        return np.array(sorted(self._rows), np.int64)

    def resident_bytes(self) -> int:
        """Host bytes of all stored client rows — the number the million-
        client benchmarks report as resident client-state memory."""
        return sum(leaf.nbytes for row in self._rows.values() for leaf in row)

    def _ensure_treedef(self):
        if self._treedef is None:
            probe = jax.eval_shape(self.init_fn, np.zeros(1, np.int64))
            self._treedef = jax.tree_util.tree_structure(probe)
        return self._treedef

    def _insert(self, ids: np.ndarray, slab_leaves: list) -> None:
        for j, cid in enumerate(ids):
            self._rows[int(cid)] = [leaf[j] for leaf in slab_leaves]

    def gather(self, ids) -> "jax.typing.ArrayLike":
        """The stacked (len(ids), ...) slab for the given global ids
        (duplicates allowed — pad lanes repeat a real id).  Missing ids are
        initialized through ``init_fn`` in one batched call."""
        ids = np.asarray(ids, np.int64)
        missing = np.unique([i for i in ids if int(i) not in self._rows])
        if missing.size:
            # pad the init batch to the gather size (the slab size — fixed
            # across chunks): every distinct batch shape costs a fresh
            # trace/compile of the vmapped init, and at small K the
            # collision-dependent |missing| varies chunk to chunk
            n_miss = int(missing.size)
            padded = (missing if n_miss >= len(ids) else np.concatenate(
                [missing, np.full(len(ids) - n_miss, missing[0], np.int64)]))
            with obs.span("cohort.lazy_init", "cohort", n=n_miss):
                fresh = self.init_fn(padded)
                leaves, self._treedef = jax.tree_util.tree_flatten(fresh)
                self._insert(missing, [np.asarray(l)[:n_miss]
                                       for l in jax.device_get(leaves)])
        reg = obs.current_registry()
        if reg is not None:
            reg.counter("cohort.gathers").inc()
            reg.counter("cohort.lazy_inits").inc(int(missing.size))
            reg.gauge("cohort.touched_clients").set(len(self._rows))
        treedef = self._ensure_treedef()
        stacked = [np.stack([self._rows[int(i)][j] for i in ids])
                   for j in range(treedef.num_leaves)]
        return jax.tree_util.tree_unflatten(treedef, stacked)

    def scatter(self, ids, slab, n_real: Optional[int] = None) -> None:
        """Write slab rows back: lane s's leaves become the stored state of
        client ``ids[s]``, for s < n_real only — pad lanes (duplicated ids
        past ``n_real``) never touch the store."""
        ids = np.asarray(ids, np.int64)
        n = len(ids) if n_real is None else int(n_real)
        leaves = [np.asarray(l)
                  for l in jax.device_get(jax.tree_util.tree_flatten(slab)[0])]
        self._insert(ids[:n], leaves)
        reg = obs.current_registry()
        if reg is not None:
            reg.counter("cohort.scatters").inc()

    # ---------------------------------------------------------- checkpoint --
    def save(self, path: str) -> None:
        ids = self.ids()
        if ids.size == 0:
            save_pytree(path, {"ids": ids, "leaves": []})
            return
        stacked = self.gather(ids)
        save_pytree(path, {"ids": ids,
                           "leaves": jax.tree_util.tree_flatten(stacked)[0]})

    def load(self, path: str) -> None:
        raw = load_pytree(path)
        self._rows.clear()
        ids = np.asarray(raw["ids"], np.int64)
        if ids.size:
            self._insert(ids, [np.asarray(l) for l in raw["leaves"]])


# ------------------------------------------------------------ slab planning --
def build_slab(cohorts: list[np.ndarray], slab_size: int):
    """(padded_ids (S,), n_real) for one chunk: the sorted ascending union
    of the chunk's cohort id arrays, padded to the *static* ``slab_size``
    with duplicates of the first id (mask-0 in every round, excluded from
    write-back).  ``slab_size`` must be >= the union size — callers fix it
    at ``chunk_rounds * active_budget`` (capped at K), the union's maximum."""
    union = np.unique(np.concatenate([np.asarray(c, np.int64)
                                      for c in cohorts]))
    n_real = int(union.size)
    if n_real > slab_size:
        raise ValueError(f"slab_size {slab_size} < {n_real} distinct "
                         f"cohort ids in this chunk")
    pad = np.full(slab_size - n_real, union[0] if n_real else 0, np.int64)
    return np.concatenate([union, pad]), n_real


def slab_ctx_plan(plans, slab_ids: np.ndarray, n_real: int) -> dict:
    """Densify a chunk of cohort plans onto the slab: (k, S) ``mask`` /
    ``stale`` ctx-plan arrays (NumPy; `CohortRunner` converts) where lane s
    of round i is 1 iff ``slab_ids[s]`` is in plan i's cohort.  Pad lanes
    (s >= n_real) stay 0 — their ids duplicate lane 0's, so membership is
    resolved by lane position, never by id."""
    k, S = len(plans), len(slab_ids)
    mask = np.zeros((k, S), np.float32)
    stale = np.zeros((k, S), np.int32)
    real = slab_ids[:n_real]
    for i, p in enumerate(plans):
        lanes = np.searchsorted(real, np.asarray(p.ids, np.int64))
        mask[i, lanes] = 1.0
        stale[i, lanes] = np.asarray(p.staleness, np.int32)
    return {"mask": mask, "stale": stale}
