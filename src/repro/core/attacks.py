"""Attack models from paper Section 4.1 "(2-7) Attack settings".

  * noisy labels    - each client independently relabels C source classes to
                      C false classes (all clients are attackers; worst case);
  * noisy open data - inject N semantically-foreign samples into the open set;
  * model poisoning - Bagdasaryan et al. replacement attack (Eqs. 17-19) for
                      FL, and its DS-FL port (malicious client uploads logits
                      of a backdoored model w_x and never updates it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def noisy_label_map(key, n_classes: int, C: int) -> jax.Array:
    """Per-client class remap (n_classes,): C distinct source classes are sent
    to C distinct false classes; others map to themselves."""
    ks, kf = jax.random.split(key)
    src = jax.random.permutation(ks, n_classes)[:C]
    dst = jax.random.permutation(kf, n_classes)[:C]
    table = jnp.arange(n_classes)
    return table.at[src].set(dst)


def apply_noisy_labels(key, labels: jax.Array, n_classes: int, C: int):
    """labels: (K, I) -> noised labels; each client gets its own remap."""
    K = labels.shape[0]
    maps = jax.vmap(lambda k: noisy_label_map(k, n_classes, C))(
        jax.random.split(key, K))                         # (K, C)
    return jax.vmap(lambda m, y: jnp.take(m, y))(maps, labels)


def mix_noisy_open(open_x: jax.Array, noise_x: jax.Array, key) -> jax.Array:
    """Append foreign samples to the open set and shuffle (noisy-open attack)."""
    allx = jnp.concatenate([open_x, noise_x], axis=0)
    return jnp.take(allx, jax.random.permutation(key, allx.shape[0]), axis=0)


# ----------------------------- model poisoning -------------------------------
def poison_fl_upload(w_backdoor, w_global, K: int):
    """Eq. 19: the upload that replaces the FedAvg global model with
    w_backdoor after averaging: w_M = K*w_x - (K-1)*w_g."""
    return jax.tree.map(
        lambda wx, wg: (K * wx.astype(jnp.float32)
                        - (K - 1) * wg.astype(jnp.float32)).astype(wx.dtype),
        w_backdoor, w_global)


def make_logit_poison(apply_fn, w_backdoor, s_backdoor, malicious_idx: int = 0):
    """DS-FL port of the attack: client `malicious_idx` always uploads the
    backdoored model's logits on the open batch (never its trained model)."""

    def corrupt(probs, rng, xo=None):
        # probs: (K, n, C); replace one client's row.  The caller closes over
        # xo via functools.partial when building the round.
        return probs

    return corrupt


def logit_poison_probs(apply_fn, w_x, s_x, xo):
    logits, _ = apply_fn(w_x, s_x, xo, False)
    return jax.nn.softmax(logits.astype(jnp.float32), axis=-1)


def replace_client_probs(probs: jax.Array, malicious_probs: jax.Array,
                         idx: int = 0) -> jax.Array:
    return probs.at[idx].set(malicious_probs)
