"""DS-FL at pod scale: each federated client is one pod of the production
mesh.  Client-stacked parameters (n_clients, ...) are sharded P("pod", ...),
so the ONLY cross-pod collective in a DS-FL round is the open-batch logit
mean inside ``aggregate`` — the paper's communication claim, visible directly
as all-reduce bytes in the compiled HLO (vs. FedAvg's parameter all-reduce).

Step functions here are mesh-agnostic pure JAX; launch/ assigns shardings.

Note: the bespoke per-step training loop that used to drive these functions
directly (launch/train.py's LLM branch) is retired — new code runs them
through `core.llm_algorithms.LLMDSFLAlgorithm` / `LLMFedAvgAlgorithm` on the
unified `FedEngine`.  The round-step functions below stay as the reference
implementations the algorithm wrappers are pinned against bit-for-bit
(tests/test_llm_algorithms.py), mirroring how `protocol.DSFLEngine` backs
`DSFLAlgorithm`.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..models.api import model_logits
from ..models.base import ModelConfig
from .aggregation import era, sa, topk_compress, weighted_era, weighted_sa
from .hierarchy import hierarchical_weighted_era, hierarchical_weighted_sa
from .algorithms import (active_indices, gather_clients, masked_mean,
                         scatter_clients, scatter_zeros, select_clients)
from .losses import (distill_xent, pinned_sum, topk_distill_xent,
                     xent_int_labels)


@dataclass(frozen=True)
class LLMDsflHP:
    lr: float = 1e-4
    gamma: float = 1.0              # weight of the distillation term
    temperature: float = 0.1        # ERA
    aggregation: str = "era"        # sa | era
    agg_edges: int = 1              # two-level ERA tree width (core.hierarchy)
    aux_weight: float = 0.01        # MoE load-balance loss
    topk: int | None = None         # sparsified logit exchange (beyond paper)
    microbatches: int = 1           # gradient accumulation (activation peak /m)
    staleness_decay: float = 0.5    # async sim: weight factor per round of lag
    # engine-facing fields (`FedEngine` reads rounds/seed/open_batch; the
    # round-step functions above ignore them)
    rounds: int = 10
    seed: int = 0
    open_batch: int = 8             # |o_r| in sequences per round


# ------------------------------------------------------------ plain steps ----
def lm_loss(cfg: ModelConfig, params, batch, aux_weight: float = 0.01):
    """Next-token CE (+ MoE aux).  labels = tokens shifted left."""
    logits, aux = model_logits(cfg, params, batch)
    labels = jnp.concatenate([batch["tokens"][:, 1:],
                              batch["tokens"][:, -1:]], axis=1)
    return xent_int_labels(logits, labels) + aux_weight * aux


def sgd_train_step(cfg: ModelConfig, params, batch, lr: float,
                   aux_weight: float = 0.01):
    """Benchmark local step ("1. Update" at LLM scale).  Plain SGD is the
    paper-faithful optimizer; large-model memory fits without moments."""
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(cfg, p, batch, aux_weight))(params)
    new = jax.tree.map(lambda p, g: p - (lr * g).astype(p.dtype), params, grads)
    return new, loss


# ------------------------------------------------------- DS-FL hybrid step ---
def dsfl_client_loss(cfg: ModelConfig, params, private_batch, open_batch,
                     teacher, hp: LLMDsflHP):
    """CE on private tokens + gamma * KD on the open batch (Eqs. 1 + 10 fused
    into one local step — the per-round client compute of DS-FL)."""
    ce = lm_loss(cfg, params, private_batch, hp.aux_weight)
    logits_o, _ = model_logits(cfg, params, open_batch)
    if hp.topk is not None:
        tv, ti = teacher
        kd = topk_distill_xent(logits_o, tv, ti)
    else:
        kd = distill_xent(logits_o, teacher)
    return ce + hp.gamma * kd


def _split_mb(tree, m: int):
    return jax.tree.map(
        lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), tree)


def dsfl_client_step(cfg: ModelConfig, params, private_batch, open_batch,
                     teacher, hp: LLMDsflHP):
    if hp.microbatches <= 1:
        loss, grads = jax.value_and_grad(
            lambda p: dsfl_client_loss(cfg, p, private_batch, open_batch,
                                       teacher, hp))(params)
    else:
        # gradient accumulation: scan over microbatches, fp32 accumulators
        m = hp.microbatches
        mbs = (_split_mb(private_batch, m), _split_mb(open_batch, m),
               _split_mb(teacher, m))

        def body(acc, mb):
            g_acc, l_acc = acc
            pb, ob, tb = mb
            l, g = jax.value_and_grad(
                lambda p: dsfl_client_loss(cfg, p, pb, ob, tb, hp))(params)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32) / m,
                                 g_acc, g)
            return (g_acc, l_acc + l / m), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), _ = jax.lax.scan(body, (g0, jnp.float32(0.0)), mbs)
    new = jax.tree.map(lambda p, g: p - (hp.lr * g).astype(p.dtype),
                       params, grads)
    return new, loss


# ----------------------------------------------------------- round step ------
def predict_open_probs(cfg: ModelConfig, params, open_batch):
    """"2. Prediction": per-token class distribution on the open batch."""
    logits, _ = model_logits(cfg, params, open_batch)
    return jax.nn.softmax(logits.astype(jnp.float32), axis=-1
                          ).astype(jnp.bfloat16)


def _is_sparse_round(K: int, hp: LLMDsflHP, weights, active_budget) -> bool:
    """The (static, trace-time) predicate routing a round through the
    participation-sparse gather plane.  Shared by the exchange and finish
    halves so a split round can never disagree with the fused one about
    which plane it is on."""
    return (weights is not None and active_budget is not None
            and active_budget < K and hp.topk is None)


def dsfl_exchange(cfg: ModelConfig, stacked_params, open_batch,
                  hp: LLMDsflHP, weights=None, mask=None,
                  active_budget=None):
    """The WIRE leg of a DS-FL round: "2. Prediction" + "3. Upload".

    Everything in the round up to (and including) the cross-pod
    all-gather, and nothing after it: clients predict on the shared open
    batch and their uploads leave the pod.  Returns the in-flight
    exchange buffers `dsfl_round_finish` consumes —

      * ``hp.topk``: the pod-gathered ``(values, indices)`` pair — the
        (K, B, S, k) compressed uploads after the explicit shard_map
        all-gather (k*(4+4) bytes/token of inter-pod traffic);
      * dense: the full (K, B, S, V) probability stack;
      * participation-sparse: the (m, B, S, V) active-lane stack (the
        finish leg scatters it into exact zeros).

    Splitting here is what lets the engine's pipelined scan issue round
    r's all-gather before round r's compute leg: the buffers returned
    here depend only on the round's *input* params, while most of the
    finish leg (the private-data CE branch of the hybrid client step)
    never touches them — so a latency-hiding scheduler can overlap the
    gather with that compute without changing a single op.  The split is
    pure restructuring: ``dsfl_round_step`` is literally
    ``dsfl_round_finish(..., dsfl_exchange(...))``, so fused and split
    rounds are the same jaxpr and the parity pins stay bitwise."""
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    if _is_sparse_round(K, hp, weights, active_budget):
        act = weights if mask is None else mask
        idx = active_indices(act, active_budget)
        params_m = gather_clients(stacked_params, idx)
        probs_m = jax.vmap(lambda p: predict_open_probs(cfg, p, open_batch)
                           )(params_m)                      # (m, B, S, V)
        return (probs_m,)
    probs = jax.vmap(lambda p: predict_open_probs(cfg, p, open_batch)
                     )(stacked_params)                     # (Kc, B, S, V)
    if hp.topk is not None:
        tv, ti = jax.vmap(lambda pr: topk_compress(pr, hp.topk))(probs)
        # force pod-replication of the SMALL uploads (the all-gather is the
        # exchange); densification and ERA then run without dense collectives
        # The exchange leg as an EXPLICIT collective: left to GSPMD, the
        # partitioner moves the pod-replication point after densification
        # and all-gathers the dense teacher (measured: 10 GB cross-pod).
        # A pod-axis shard_map pins the all-gather on the (value, index)
        # pairs — k*(4+4) bytes/token of inter-pod traffic.
        _get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
        mesh = _get_mesh() if _get_mesh is not None else None
        if mesh is not None and "pod" in mesh.axis_names:
            from jax.sharding import PartitionSpec as P
            sm = jax.shard_map(
                lambda v, i: (jax.lax.all_gather(v[0], "pod"),
                              jax.lax.all_gather(i[0], "pod")),
                mesh=mesh,
                in_specs=(P("pod"), P("pod")),
                out_specs=(P(), P()),
                axis_names={"pod"})
            tv, ti = sm(tv, ti)
        return (tv, ti)
    return (probs,)


def dsfl_round_finish(cfg: ModelConfig, stacked_params, private_batches,
                      open_batch, inflight, hp: LLMDsflHP, weights=None,
                      mask=None, active_budget=None):
    """The COMPUTE leg of a DS-FL round: "4. Aggregation" + "5. Broadcast"
    + the hybrid CE+KD client step, consuming the exchange buffers
    `dsfl_exchange` put in flight.  The private-batch CE branch of
    ``dsfl_client_step`` has no data dependency on ``inflight`` — only
    the KD term and the open-branch backward seed do — which is the slack
    the pipelined schedule hides the wire behind."""
    from ..models.shardctx import constrain
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    if _is_sparse_round(K, hp, weights, active_budget):
        return _dsfl_finish_sparse(cfg, stacked_params, private_batches,
                                   open_batch, inflight, hp, weights, mask,
                                   active_budget)
    if hp.topk is not None:
        tv, ti = inflight
        # shard-local densify: iota-compare instead of scatter (a scatter
        # into a vocab-sharded output would replicate the dense tensor)
        V = cfg.eff_vocab     # probs carry the padded (TP-divisible) vocab
        iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, 1, V), 4)
        onehot = (iota == ti[..., None]).astype(jnp.float32)   # (Kc,B,S,k,V)
        dense = jnp.einsum("cbsk,cbskv->cbsv", tv.astype(jnp.float32), onehot)
        dense = constrain(dense, None, "batch", None, "model")
        teacher = _aggregate_teacher(dense, hp, weights)
        teacher = constrain(teacher, "batch", None, "model")
        # the exchange leg is compressed; the pod-local distillation uses the
        # dense (vocab-sharded) teacher — no top_k over a sharded axis
        import dataclasses
        hp = dataclasses.replace(hp, topk=None)
    else:
        (probs,) = inflight
        teacher = _aggregate_teacher(probs, hp, weights)

    new_params, losses = jax.vmap(
        lambda p, b: dsfl_client_step(cfg, p, b, open_batch, teacher, hp)
    )(stacked_params, private_batches)
    if weights is not None:
        # absent clients neither update nor average into the loss
        m = (weights if mask is None else mask).astype(jnp.float32) > 0
        new_params = select_clients(m, new_params, stacked_params)
        return new_params, masked_mean(losses, m)
    return new_params, jnp.mean(losses)


def dsfl_round_step(cfg: ModelConfig, stacked_params, private_batches,
                    open_batch, hp: LLMDsflHP, weights=None, mask=None,
                    active_budget=None):
    """One full DS-FL round over the pod-sharded client axis: the
    composition ``dsfl_round_finish(..., dsfl_exchange(...))``.

    stacked_params: pytree with leading (n_clients,) axis, sharded P("pod",.).
    private_batches: each leaf (n_clients, B, ...).  open_batch: (B, ...) —
    identical on every pod (the shared open set).

    The mean over axis 0 inside sa/era is the ONLY cross-pod collective.
    With hp.topk, clients compress their logits BEFORE the exchange (the
    paper's upload leg): the cross-pod traffic becomes an all-gather of
    (value, index) pairs — k*(4+4) bytes/token instead of V*2 — and the
    dense densify+ERA runs pod-locally on the gathered pairs.

    ``weights`` (K,), when given, turns the exchange into the sim layer's
    partial-participation round: zero-weight (absent) clients contribute
    nothing to the aggregate and keep their parameters; stale-decayed
    weights discount async contributions.  ``mask`` (K,) separately names
    the participants — a stale participant whose aggregation weight
    decayed to exactly zero still trains and averages into the loss, same
    as the core `algorithms` path.  ``None`` (the default) is the exact
    full-participation path the parity tests pin bit-for-bit.

    ``active_budget=m`` (with ``weights``) runs the participation-sparse
    round: prediction and the hybrid client step execute on only the m
    gathered active lanes of the pod-sharded stack, and the gathered
    uploads scatter into exact zeros before the weighted exchange — a
    ~K/m client-compute reduction, bitwise identical to the dense
    ``weights=`` round.  The top-k exchange keeps the dense path (its
    pinned pod-axis all-gather is shaped by the full client axis).
    """
    inflight = dsfl_exchange(cfg, stacked_params, open_batch, hp,
                             weights=weights, mask=mask,
                             active_budget=active_budget)
    return dsfl_round_finish(cfg, stacked_params, private_batches,
                             open_batch, inflight, hp, weights=weights,
                             mask=mask, active_budget=active_budget)


def _dsfl_finish_sparse(cfg: ModelConfig, stacked_params, private_batches,
                        open_batch, inflight, hp: LLMDsflHP, weights, mask,
                        active_budget: int):
    """Participation-sparse finish leg: same gather -> compute -> scatter
    plane as `algorithms.DSFLAlgorithm._sparse_round`, along the
    pod-sharded client axis.  Bitwise identical to the dense ``weights=``
    round (tests/test_llm_dsfl.py): active lanes see the same per-client
    math, and the scattered zero lanes multiply against the same
    exact-zero aggregation weights the dense stack's lanes do.  ``idx``
    is re-derived from the ctx (a pure, cheap argsort) rather than
    carried in ``inflight``, so the exchange buffers stay O(m)."""
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    act = weights if mask is None else mask
    idx = active_indices(act, active_budget)
    act_m = jnp.take(act, idx, axis=0)
    params_m = gather_clients(stacked_params, idx)
    batches_m = gather_clients(private_batches, idx)

    (probs_m,) = inflight                                   # (m, B, S, V)
    teacher = _aggregate_teacher(scatter_zeros(probs_m, K, idx), hp, weights)

    new_m, losses_m = jax.vmap(
        lambda p, b: dsfl_client_step(cfg, p, b, open_batch, teacher, hp)
    )(params_m, batches_m)
    new_m = select_clients(act_m.astype(jnp.float32) > 0, new_m, params_m)
    new_params = scatter_clients(new_m, stacked_params, idx)
    losses = scatter_zeros(losses_m, K, idx)
    return new_params, masked_mean(losses, act.astype(jnp.float32) > 0)


def _aggregate_teacher(probs, hp: LLMDsflHP, weights):
    """sa/era over the client axis; the weighted variants zero out absent
    clients and decay stale ones when the sim supplies ``weights``.
    ``hp.agg_edges > 1`` reduces the client axis through the two-level
    edge -> server tree (`core.hierarchy`) — on a pod-sharded client axis
    each edge's partial sum is shard-local, so the cross-pod exchange
    carries n_edges (n, S, V) partials instead of K upload stacks.  The
    parity/tolerance contract is `core.hierarchy`'s: bitwise at one edge,
    pinned tolerance deeper."""
    if hp.agg_edges > 1:
        w = (jnp.ones((probs.shape[0],), jnp.float32)
             if weights is None else weights)
        agg = (hierarchical_weighted_era(probs, w, hp.temperature,
                                         hp.agg_edges)
               if hp.aggregation == "era"
               else hierarchical_weighted_sa(probs, w, hp.agg_edges))
    elif weights is None:
        agg = era(probs, hp.temperature) if hp.aggregation == "era" \
            else sa(probs)
    else:
        agg = (weighted_era(probs, weights, hp.temperature)
               if hp.aggregation == "era" else weighted_sa(probs, weights))
    return agg.astype(jnp.bfloat16)


def fedavg_round_step(cfg: ModelConfig, stacked_params, private_batches,
                      lr: float, weights=None, mask=None,
                      active_budget=None):
    """Benchmark 1 at pod scale: local step then parameter mean over the pod
    axis — its all-reduce bytes = model size (the paper's comparison).

    ``weights`` (K,), when given, makes the mean a weighted average (zero
    for absent clients, staleness-decayed for async ones; client state is
    ephemeral in FedAvg, so masking the average is the whole
    partial-participation round); ``mask`` (K,) names the participants
    whose losses average into the metric even if their weight decayed to
    zero.  ``None`` is the exact pinned path.

    ``active_budget=m`` (with ``weights``) gathers the m active lanes,
    trains only those, and scatters into exact zeros — the Eq. 3 weighted
    mean multiplies the zero lanes by the same exact-zero weights the
    dense round's lanes get, so the result is bitwise identical."""
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    if (weights is not None and active_budget is not None
            and active_budget < K):
        act = weights if mask is None else mask
        idx = active_indices(act, active_budget)
        new_m, losses_m = jax.vmap(
            lambda p, b: sgd_train_step(cfg, p, b, lr)
        )(gather_clients(stacked_params, idx),
          gather_clients(private_batches, idx))
        new_params = jax.tree.map(lambda a: scatter_zeros(a, K, idx), new_m)
        losses = scatter_zeros(losses_m, K, idx)
    else:
        new_params, losses = jax.vmap(
            lambda p, b: sgd_train_step(cfg, p, b, lr))(stacked_params,
                                                        private_batches)
    if weights is None:
        avg = jax.tree.map(
            lambda leaf: jnp.mean(leaf.astype(jnp.float32), axis=0,
                                  keepdims=True).astype(leaf.dtype),
            new_params)
        loss = jnp.mean(losses)
    else:
        w = weights.astype(jnp.float32)
        # dot-lowered total: bitwise-stable across the dense/sparse programs
        w = w / jnp.maximum(pinned_sum(w), 1e-9)
        avg = jax.tree.map(
            lambda leaf: jnp.einsum("k,k...->...", w,
                                    leaf.astype(jnp.float32)
                                    )[None].astype(leaf.dtype), new_params)
        m = (weights if mask is None else mask).astype(jnp.float32) > 0
        loss = masked_mean(losses, m)
    broad = jax.tree.map(lambda a, ref: jnp.broadcast_to(a, ref.shape),
                         avg, new_params)
    return broad, loss
