"""Loss functions (fp32 statistics).  The distillation loss has a fused
Pallas path (`repro.kernels.distill_loss`) selected by ``use_kernel``.

The scalar loss means are computed through ``pinned_mean``: XLA
reassociates a plain fused ``reduce`` differently depending on the
surrounding program, so the *same* per-sample CE values can mean to
different last-bit floats in two differently-shaped programs — which would
break the participation-sparse round's bitwise-parity guarantee (the
sparse and the dense masked rounds are different programs computing
identical per-client losses).  A ``dot``-lowered sum is emitted through
XLA's dot path, whose lane order is context-stable (empirically: every
einsum/matmul in the round is, only plain reduces wobble), and it batches
cleanly under ``vmap``."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def pinned_sum(v):
    """Context-stable summation: lowered as a dot, not a plain reduce, so
    two differently-fused programs summing bitwise-identical inputs agree
    bitwise (see module docstring).  Sums over *all* axes."""
    v = v.astype(F32).ravel()
    return jnp.dot(v, jnp.ones_like(v))


def pinned_mean(ce, mask=None):
    """Mean (or mask-weighted mean) of a per-sample loss tensor, with the
    reduction order pinned across programs (see module docstring)."""
    if mask is not None:
        return pinned_sum(ce * mask) / jnp.maximum(pinned_sum(mask), 1.0)
    return pinned_sum(ce) / ce.size


def log_softmax(logits):
    x = logits.astype(F32)
    m = jnp.max(x, axis=-1, keepdims=True)
    s = x - m
    return s - jnp.log(jnp.sum(jnp.exp(s), axis=-1, keepdims=True))


def softmax_xent(logits, labels_onehot, mask=None):
    """Cross-entropy vs hard one-hot or soft targets. logits: (..., C)."""
    ls = log_softmax(logits)
    ce = -jnp.sum(labels_onehot.astype(F32) * ls, axis=-1)
    return pinned_mean(ce, mask)


def xent_int_labels(logits, labels, mask=None):
    """CE with integer labels, avoids materializing one-hots over big vocabs."""
    ls = log_softmax(logits)
    ce = -jnp.take_along_axis(ls, labels[..., None].astype(jnp.int32),
                              axis=-1)[..., 0]
    return pinned_mean(ce, mask)


def distill_xent(student_logits, teacher_probs, mask=None, use_kernel=False,
                 interpret=None):
    """KD loss: CE(teacher_probs || softmax(student_logits)).  This is the
    DS-FL "6. Distillation" objective (Eq. 10) with the global logit as soft
    target.  On the kernel path ``interpret=None`` auto-selects interpret
    mode on CPU only (the `kernels.ops` convention), so the fused kernel
    actually compiles on TPU/GPU; pass True/False to force either mode."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.distill_loss(student_logits, teacher_probs, mask,
                                 interpret=interpret)
    return softmax_xent(student_logits, teacher_probs, mask)


def topk_distill_xent(student_logits, topk_p, topk_i, mask=None):
    """KD against a sparsified teacher: sum over the k kept entries only.
    topk_p: (..., k) renormalized probs; topk_i: (..., k) vocab indices."""
    ls = log_softmax(student_logits)
    sel = jnp.take_along_axis(ls, topk_i.astype(jnp.int32), axis=-1)
    ce = -jnp.sum(topk_p.astype(F32) * sel, axis=-1)
    return pinned_mean(ce, mask)


def entropy(probs, axis=-1):
    p = probs.astype(F32)
    return -jnp.sum(p * jnp.log(jnp.clip(p, 1e-12, 1.0)), axis=axis)


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(F32))
