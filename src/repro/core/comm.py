"""Analytic per-round communication accounting (reproduces paper Tables 1-2).

Conventions (matching the paper's numbers exactly):
  * payloads are 32-bit floats (4 bytes);
  * a round's cost = K client uploads + 1 multicast broadcast;
  * DS-FL additionally pays a one-off open-dataset distribution cost
    (ComU@I in Table 3): I_o * sample_bytes, float32 samples;
  * FD uploads per-class logits (C * C floats per client);
  * DS-FL uploads per-sample logits (|o_r| * C floats per client);
  * FL uploads the full parameter vector.
Verified against Table 1/2: e.g. MNIST-CNN FL = 583,242*4*(100+1) = 236 MB,
IMDb FD = 2*2*4*(10+1) = 176 B, Reuters DS-FL = 1000*46*4*(10+1) = 2.0 MB.
"""
from __future__ import annotations

from dataclasses import dataclass

FLOAT_BYTES = 4
HALF_BYTES = 2
INT_BYTES = 4
INT8_BYTES = 1


@dataclass(frozen=True)
class CommModel:
    n_clients: int
    n_classes: int
    n_params: int
    open_batch: int = 1000       # |o_r|

    # ---- per-round costs (bytes) ----
    def fl_round(self) -> int:
        return self.n_params * FLOAT_BYTES * (self.n_clients + 1)

    def fd_round(self) -> int:
        payload = self.n_classes * self.n_classes * FLOAT_BYTES
        return payload * (self.n_clients + 1)

    def dsfl_round(self) -> int:
        payload = self.open_batch * self.n_classes * FLOAT_BYTES
        return payload * (self.n_clients + 1)

    def dsfl_topk_round(self, k: int) -> int:
        """Beyond-paper sparsified exchange: k (value, index) pairs/sample."""
        payload = self.open_batch * k * (FLOAT_BYTES + INT_BYTES)
        return payload * (self.n_clients + 1)

    def dsfl_fp16_round(self) -> int:
        """Beyond-paper half-precision logit exchange."""
        payload = self.open_batch * self.n_classes * HALF_BYTES
        return payload * (self.n_clients + 1)

    def dsfl_int8_round(self) -> int:
        """Beyond-paper affine-quantized logit exchange: 1 byte per logit
        plus the per-tensor (scale, zero) fp32 sidecar (`wire.Int8Codec`)."""
        payload = (self.open_batch * self.n_classes * INT8_BYTES
                   + 2 * FLOAT_BYTES)
        return payload * (self.n_clients + 1)

    def round_bytes(self, method: str, topk: int | None = None) -> int:
        if method == "fl":
            return self.fl_round()
        if method == "fd":
            return self.fd_round()
        if method in ("dsfl", "dsfl_sa", "dsfl_era"):
            return self.dsfl_round()
        if method == "dsfl_topk":
            return self.dsfl_topk_round(topk or 32)
        if method == "dsfl_fp16":
            return self.dsfl_fp16_round()
        if method == "dsfl_int8":
            return self.dsfl_int8_round()
        if method == "single":
            return 0
        raise ValueError(method)

    # ---- one-off costs ----
    def open_set_distribution(self, n_open_total: int, sample_floats: int) -> int:
        """ComU@I: multicast of the unlabeled open dataset."""
        return n_open_total * sample_floats * FLOAT_BYTES


def fmt_bytes(b: float) -> str:
    for unit in ("B", "kB", "MB", "GB", "TB"):
        if abs(b) < 1000:
            return f"{b:.1f} {unit}"
        b /= 1000
    return f"{b:.1f} PB"
