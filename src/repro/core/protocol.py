"""DS-FL engine (paper Algorithm 1) at "paper scale": K clients simulated as
a vmapped leading axis of stacked parameter pytrees; the server's aggregation
is a mean over that axis (on a TPU mesh this axis is sharded over pods and
the mean lowers to the logit all-reduce — see core/llm_dsfl.py).

Round structure (Fig. 1 (c)):
  1. Update       - local SGD on private data (vmap of client.local_update)
  2. Prediction   - local probs on the shared open-batch o_r (Eq. 9)
  3-5. Upload/Aggregate/Broadcast - aggregation.aggregate (SA / ERA)
  6. Distillation - clients AND the server global model train on (D^{o_r}, T̂)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..optim import optimizers as opt_lib
from .aggregation import aggregate
from .client import LocalSpec, local_distill, local_update, predict_probs
from .losses import accuracy, entropy


@dataclass(frozen=True)
class DSFLConfig:
    rounds: int = 30
    local_epochs: int = 5
    distill_epochs: int = 5
    batch_size: int = 100
    open_batch: int = 1000          # |o_r|
    lr: float = 0.1
    lr_distill: float = 0.1
    optimizer: str = "sgd"
    aggregation: str = "era"        # sa | era | weighted_era
    temperature: float = 0.1        # ERA softmax temperature
    staleness_decay: float = 0.5    # async: weight factor per round of lag
    seed: int = 0


def make_dsfl_round(apply_fn: Callable, hp: DSFLConfig,
                    corrupt: Optional[Callable] = None):
    """Build the jittable one-round function.

    corrupt(probs (K, n, C), rng) -> probs lets attack experiments inject
    malicious local logits between "2. Prediction" and "4. Aggregation"."""
    opt_u = opt_lib.make(hp.optimizer, hp.lr)
    opt_d = opt_lib.make(hp.optimizer, hp.lr_distill)
    spec_u = LocalSpec(apply_fn, opt_u, hp.local_epochs, hp.batch_size)
    spec_d = LocalSpec(apply_fn, opt_d, hp.distill_epochs,
                       min(hp.batch_size, hp.open_batch))

    def round_fn(wk, sk, ouk, odk, wg, sg, odg, x, y, open_x, o_idx, rng):
        K = x.shape[0]
        r1, r2, r3, r4 = jax.random.split(rng, 4)
        xo = jnp.take(open_x, o_idx, axis=0)

        # 1. Update
        wk, sk, ouk, up_loss = jax.vmap(
            lambda w, s, o, xk, yk, rk: local_update(spec_u, w, s, o, xk, yk, rk)
        )(wk, sk, ouk, x, y, jax.random.split(r1, K))

        # 2. Prediction (local logits on o_r)
        probs = jax.vmap(lambda w, s: predict_probs(apply_fn, w, s, xo))(wk, sk)
        if corrupt is not None:
            probs = corrupt(probs, xo, r3)

        # 3-5. Upload / Aggregation / Broadcast
        global_logit = aggregate(probs, hp.aggregation, hp.temperature)
        sa_entropy = jnp.mean(entropy(jnp.mean(probs, axis=0)))
        g_entropy = jnp.mean(entropy(global_logit))

        # 6. Distillation (clients, Eq. 10)
        wk, sk, odk, d_loss = jax.vmap(
            lambda w, s, o, rk: local_distill(spec_d, w, s, o, xo,
                                              global_logit, rk)
        )(wk, sk, odk, jax.random.split(r2, K))

        # 6'. server global model (Eq. 11) — own key, so the server's distill
        # minibatch permutations are independent of the clients' (r2)
        wg, sg, odg, gd_loss = local_distill(spec_d, wg, sg, odg, xo,
                                             global_logit, r4)

        metrics = {"update_loss": jnp.mean(up_loss),
                   "distill_loss": jnp.mean(d_loss),
                   "server_distill_loss": gd_loss,
                   "global_entropy": g_entropy,
                   "sa_entropy": sa_entropy}
        return (wk, sk, ouk, odk, wg, sg, odg), metrics

    return round_fn


@dataclass
class DSFLEngine:
    """Python-level orchestration: round jitting, o_r sampling, eval, history.

    .. deprecated:: use ``repro.core.engine.FedEngine`` with
       ``repro.core.algorithms.DSFLAlgorithm`` — the algorithm-agnostic
       trainer that also runs FD and FedAvg.  This class is kept as the
       golden reference for the parity test and for old callers."""
    apply_fn: Callable
    hp: DSFLConfig
    eval_fn: Callable                      # (w, s) -> dict of metrics
    corrupt: Optional[Callable] = None
    history: list = field(default_factory=list)

    def __post_init__(self):
        self._round = jax.jit(make_dsfl_round(self.apply_fn, self.hp,
                                              self.corrupt))

    def init_states(self, wk, sk, wg, sg):
        opt_u = opt_lib.make(self.hp.optimizer, self.hp.lr)
        opt_d = opt_lib.make(self.hp.optimizer, self.hp.lr_distill)
        ouk = jax.vmap(opt_u.init)(wk)
        odk = jax.vmap(opt_d.init)(wk)
        odg = opt_d.init(wg)
        return ouk, odk, odg

    def run(self, wk, sk, wg, sg, x, y, open_x, log_every: int = 1):
        hp = self.hp
        rng = jax.random.PRNGKey(hp.seed)
        ouk, odk, odg = self.init_states(wk, sk, wg, sg)
        n_open = open_x.shape[0]
        for r in range(hp.rounds):
            rng, rk, ri = jax.random.split(rng, 3)
            o_idx = jax.random.choice(ri, n_open,
                                      (min(hp.open_batch, n_open),),
                                      replace=False)
            (wk, sk, ouk, odk, wg, sg, odg), m = self._round(
                wk, sk, ouk, odk, wg, sg, odg, x, y, open_x, o_idx, rk)
            if (r + 1) % log_every == 0:
                rec = {"round": r + 1,
                       **{k: float(v) for k, v in m.items()},
                       **self.eval_fn(wg, sg)}
                self.history.append(rec)
        return wk, sk, wg, sg


def make_eval_fn(apply_fn, x_test, y_test, batch: int = 1000):
    @jax.jit
    def _logits(w, s):
        logits, _ = apply_fn(w, s, x_test, False)
        return logits

    def eval_fn(w, s):
        logits = _logits(w, s)
        return {"test_acc": float(accuracy(logits, y_test))}

    return eval_fn
