"""Benchmark 2: Federated Distillation (Jeong et al. 2018; paper §2.2).

Clients exchange *per-class average* probability vectors instead of
per-sample logits:

  Eq. 4: t_{k,n} = mean of F(d|w_k) over client k's samples with label n
  Eq. 5: t_{g,n} = mean over clients that own class n
  Eq. 6: per-sample distill target debiases the client's own contribution
  Eq. 7: update with CE(labels) + gamma * CE(distill target)

Under strong non-IID this collapses to near-one-hot knowledge (paper Fig. 2),
which is exactly the failure mode DS-FL fixes — so FD must be implemented
faithfully to reproduce the gap."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .client import LocalSpec, local_update, predict_probs

F32 = jnp.float32


def per_label_logits(apply_fn, params, state, x, y, n_classes: int):
    """Eq. 4 for one client -> (t (C, C), present (C,))."""
    probs = predict_probs(apply_fn, params, state, x)       # (I, C)
    oh = jax.nn.one_hot(y, n_classes, dtype=F32)            # (I, C)
    counts = jnp.sum(oh, axis=0)                            # (C,)
    sums = oh.T @ probs                                     # (C, C)
    t = sums / jnp.maximum(counts[:, None], 1.0)
    return t, counts > 0


def aggregate_fd(tk: jax.Array, present: jax.Array):
    """Eq. 5: class-wise mean over owning clients.
    tk: (K, C, C), present: (K, C) -> (t_g (C, C), n_owners (C,)).

    Both cross-client sums are einsum contractions rather than plain
    reduces so their lane order is context-stable: the participation-sparse
    FD round and the dense masked round are different XLA programs summing
    bitwise-identical inputs, and a fused plain reduce is free to
    reassociate differently in each (see `losses.pinned_sum`)."""
    m = present.astype(F32)                                 # (K, C)
    n_own = jnp.einsum("k,kc->c", jnp.ones((m.shape[0],), F32), m)
    tg = jnp.einsum("kc,kcd->cd", m, tk.astype(F32)) \
        / jnp.maximum(n_own[:, None], 1.0)
    return tg, n_own


def distill_targets(tg, tk_self, n_own, y):
    """Eq. 6 per sample: remove the client's own logit from the average.
    tg: (C, C); tk_self: (C, C); n_own: (C,); y: (I,) -> (I, C)."""
    K_nl = jnp.maximum(n_own, 2.0)                          # guard |K|-1 >= 1
    debias = (K_nl[:, None] * tg - tk_self) / (K_nl[:, None] - 1.0)
    # clients that are sole owner of a class fall back to the global average
    debias = jnp.where((n_own > 1)[:, None], debias, tg)
    return jnp.take(debias, y, axis=0)


def make_fd_round(spec: LocalSpec, n_classes: int, gamma: float = 1.0):
    """One FD round over stacked clients.  Returns updated stacks + the global
    per-class logit (for Fig. 2-style analysis).

    .. deprecated:: prefer ``algorithms.FDAlgorithm`` under
       ``engine.FedEngine`` (same math, unified API)."""

    def round_fn(wk, sk, ok, x, y, rng):
        K = x.shape[0]
        tk, present = jax.vmap(
            lambda w, s, xk, yk: per_label_logits(spec.apply_fn, w, s, xk, yk,
                                                  n_classes))(wk, sk, x, y)
        tg, n_own = aggregate_fd(tk, present)
        rngs = jax.random.split(rng, K)

        def per_client(w, s, o, xk, yk, tkk, rk):
            tgt = distill_targets(tg, tkk, n_own, yk)
            return local_update(spec, w, s, o, xk, yk, rk,
                                distill_extra=tgt, gamma=gamma)

        wk, sk, ok, losses = jax.vmap(per_client)(wk, sk, ok, x, y, tk, rngs)
        return wk, sk, ok, jnp.mean(losses), tg

    return round_fn
