"""`FedEngine` — one algorithm-agnostic federated trainer.

Generalizes the seed `protocol.DSFLEngine` to any `FedAlgorithm`: jits the
algorithm's round once, samples the shared open batch o_r (when the
algorithm uses one), runs test-set eval through ``algo.eval_params``,
accumulates a scalar history, measures wire bytes through a `wire.Codec`,
and checkpoints the full typed `RoundState` with the msgpack backend —
together with the round counter and history, so save/load/run resumes the
exact RNG stream without the caller hand-tracking ``start_round``.

``run(..., chunk_rounds=k)`` compiles k federated rounds into a single
``jax.lax.scan``: the per-round RNG chain, the open-batch draw and the
algorithm's round all live inside one jit, with per-round metrics stacked
on device and pulled to the host once per chunk — so the Python-loop
overhead (one dispatch + one ``float()`` sync per round) disappears from
the hot path.  The scanned path is **bitwise identical** to the default
per-round loop (same key stream, same history), pinned by
``tests/test_engine_scan.py`` across DSFL/FD/FedAvg.

For the pod-scale LLM algorithms, pass ``mesh=`` (and optionally
``donate_state=True``): the engine builds its jit with mesh-aware
``in_shardings`` from ``algo.shardings(mesh, state, ctx)`` — the
`launch.sharding` placement rules — and donates the round state's buffers.
Both compose with ``chunk_rounds``.

RNG discipline matches the seed engine exactly (``rng, rk, ri =
split(rng, 3)`` per round; o_r drawn from ``ri``; the round keyed by
``rk``) so `DSFLAlgorithm` under this engine is bit-for-bit identical to
the reference `DSFLEngine` — asserted by ``tests/test_engine.py``.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import assert_tree_compatible, load_pytree, save_pytree
from ..obs import trace as obs
from .algorithms import BatchCtx, EMPTY, FedAlgorithm, RoundState
# re-exported so new-API callers need only this module (the implementation
# lives with the reference engine)
from .protocol import make_eval_fn  # noqa: F401
from .wire import Codec, DenseF32Codec, nbytes


def _leading_dim(tree) -> int:
    """First-axis size of a (possibly dict-of-arrays) batch pytree."""
    return jax.tree.leaves(tree)[0].shape[0]


@jax.jit
def _fast_forward_key(rng, n):
    """Advance the per-round key chain (``rng <- split(rng, 3)[0]``) past n
    completed rounds entirely on device.  ``n`` is traced, so one compiled
    loop serves every resume point — resuming at round 10k is a single
    dispatch, not 10k host-side ``jax.random.split`` calls — and the result
    is bitwise the key the host loop would produce (asserted by
    ``tests/test_engine_scan.py``)."""
    return jax.lax.fori_loop(
        0, n, lambda _, k: jax.random.split(k, 3)[0], rng)


@dataclass
class FedEngine:
    """Python-level orchestration around ``jax.jit(algo.round)``.

    ``eval_fn(params, model_state) -> dict`` is called on
    ``algo.eval_params(state)`` every ``log_every`` rounds; its scalars join
    the round metrics in ``history``.  Non-scalar round metrics (e.g. FD's
    (C, C) global logit) are kept out of the history but exposed on
    ``last_metrics``.  ``on_round(r, state) -> state`` runs un-jitted
    between rounds (attack injection, LR rescheduling, ...).

    ``mesh``: when set and the algorithm exposes ``shardings``, the round is
    jitted with mesh-aware ``in_shardings`` (built lazily from the first
    round's state/ctx).  ``donate_state=True`` donates the round-state
    buffers to the jit (halves peak params memory for the LLM algorithms).
    ``rounds_done`` counts completed rounds; it is checkpointed by
    ``save_state`` and restored by ``load_state`` so a resumed ``run``
    continues the per-round RNG chain automatically.

    ``on_chunk(rounds_done, state) -> None`` is a pure *observer* called
    whenever a freshly-computed state lands on the host: after every chunk
    on the scanned path, after every round on the loop path.  Unlike
    ``on_round``/``on_ctx`` it cannot rewrite the state, so it does NOT
    force the per-round loop — `repro.serve.swap` uses it to hot-swap a
    running server's weights at ``chunk_rounds`` boundaries while the
    training stream stays fully fused."""
    algo: FedAlgorithm
    eval_fn: Optional[Callable] = None
    codec: Codec = field(default_factory=DenseF32Codec)
    on_round: Optional[Callable] = None
    on_ctx: Optional[Callable] = None
    on_chunk: Optional[Callable] = None
    mesh: Optional[Any] = None
    donate_state: bool = False
    history: list = field(default_factory=list)
    last_metrics: dict = field(default_factory=dict)
    rounds_done: int = 0

    def __post_init__(self):
        self._round = None       # manual override slot (None = use the cache)
        self._round_cache = {}   # (state, ctx) treedef -> jitted round
        self._chunk_cache = {}   # scan signature -> jitted k-round driver
        self._round_us = {}      # schedule (overlap?) -> per-round µs samples

    def _build_round(self, state: RoundState, ctx: BatchCtx):
        kw = {}
        if self.donate_state:
            kw["donate_argnums"] = (0,)
        shard_fn = getattr(self.algo, "shardings", None)
        if self.mesh is not None and shard_fn is not None:
            state_sh, ctx_sh = shard_fn(self.mesh, state, ctx)
            kw["in_shardings"] = (state_sh, ctx_sh, None)
            # pin the output state to the same placement: round r+1 consumes
            # round r's output, so a free XLA choice here would hand the next
            # call an arg whose sharding mismatches in_shardings
            kw["out_shardings"] = (state_sh, None)
        return jax.jit(self.algo.round, **kw)

    def _get_round(self, state: RoundState, ctx: BatchCtx):
        """The jitted round for this (state, ctx) *structure*.  Keyed on the
        tree structure because ``on_ctx`` (or a sim plan) can flip
        ``ctx.mask``/``stale`` from EMPTY to arrays mid-run: a round (and its
        ``in_shardings``) built from the first round's treedef would then be
        handed a ctx it was never built for — the stale-cache landmine
        pinned by ``tests/test_engine_scan.py``."""
        if self._round is not None:
            return self._round
        key = jax.tree_util.tree_structure((state, ctx))
        fn = self._round_cache.get(key)
        if fn is None:
            fn = self._round_cache[key] = self._build_round(state, ctx)
        return fn

    def _build_chunk(self, k: int, n_open: int, n_r: int, state: RoundState,
                     ctx0: BatchCtx, plan, overlap: bool = False):
        """One jit folding k federated rounds into a ``jax.lax.scan``: the
        per-round key chain, the open-batch draw and the algorithm's round
        all run on device; metrics come back stacked over the chunk.
        ``plan`` (optional) is a dict of per-round BatchCtx overrides with a
        leading (k,) axis — e.g. a sim scheduler's participation mask —
        scanned through as per-step inputs.

        ``overlap=True`` builds the software-pipelined schedule instead:
        the algorithm's round splits into ``round_start`` (the wire leg —
        prediction + the cross-pod upload all-gather) and ``round_finish``
        (the compute leg), and the scan body finishes round r *then*
        issues round r+1's start — so r+1's exchange is already in flight
        while nothing after it in program order depends on it, and a
        latency-hiding scheduler (`launch.platform`'s ``overlap`` preset)
        can sink it under r+1's private-data update leg.  The carry
        double-buffers the in-flight exchange tensors.  Prologue (start
        round 0) + k-1 bodies + epilogue (finish round k-1) = exactly k
        starts and k finishes: same ops, same key chain (k ``split``s),
        same per-round inputs — **bitwise identical** to the sequential
        schedule (``round == finish ∘ start`` by construction; pinned by
        ``tests/test_overlap.py`` / ``tests/test_engine_scan.py``)."""
        algo = self.algo
        uses_open = algo.uses_open

        def draw(rng):
            """The engine's per-round RNG discipline, shared verbatim by
            both schedules: one 3-way split, o_r drawn from ``ri``."""
            rng, rk, ri = jax.random.split(rng, 3)
            o_idx = (jax.random.choice(ri, n_open, (n_r,), replace=False)
                     if uses_open else EMPTY)
            return rng, rk, o_idx

        def mk_ctx(ctx0, o_idx, step):
            ctx = ctx0
            if uses_open:
                ctx = dataclasses.replace(ctx, o_idx=o_idx)
            if step is not None:
                ctx = dataclasses.replace(ctx, **step)
            return ctx

        def chunk_fn(state, ctx0, rng, plan):
            def body(carry, step):
                state, rng = carry
                rng, rk, o_idx = draw(rng)
                state, m = algo.round(state, mk_ctx(ctx0, o_idx, step), rk)
                return (state, rng), m
            (state, rng), ms = jax.lax.scan(body, (state, rng), plan,
                                            length=k)
            return state, rng, ms

        def chunk_fn_pipelined(state, ctx0, rng, plan):
            # prologue: put round 0's exchange in flight
            rng, rk, o_idx = draw(rng)
            step0 = (None if plan is None
                     else jax.tree.map(lambda v: v[0], plan))
            inflight = algo.round_start(state, mk_ctx(ctx0, o_idx, step0),
                                        rk)

            def body(carry, step_next):
                state, rng, inflight, rk, o_idx, step = carry
                # finish round r with the buffers issued one body earlier...
                state, m = algo.round_finish(
                    state, mk_ctx(ctx0, o_idx, step), inflight, rk)
                # ...then issue round r+1's exchange against the fresh state
                rng, rk2, o_idx2 = draw(rng)
                inflight2 = algo.round_start(
                    state, mk_ctx(ctx0, o_idx2, step_next), rk2)
                return (state, rng, inflight2, rk2, o_idx2, step_next), m

            rest = (None if plan is None
                    else jax.tree.map(lambda v: v[1:], plan))
            carry = (state, rng, inflight, rk, o_idx, step0)
            (state, rng, inflight, rk, o_idx, step0), ms = jax.lax.scan(
                body, carry, rest, length=k - 1)
            # epilogue: round k-1's finish (its start was the last body's —
            # or the prologue's, when k == 1 and the scan runs zero bodies)
            state, m_last = algo.round_finish(
                state, mk_ctx(ctx0, o_idx, step0), inflight, rk)
            ms = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b[None]], axis=0),
                ms, m_last)
            return state, rng, ms

        if overlap:
            chunk_fn = chunk_fn_pipelined
        kw = {}
        if self.donate_state:
            kw["donate_argnums"] = (0,)
        shard_fn = getattr(algo, "shardings", None)
        if self.mesh is not None and shard_fn is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            probe = (dataclasses.replace(ctx0, o_idx=jnp.zeros((n_r,),
                                                               jnp.int32))
                     if uses_open else ctx0)
            state_sh, ctx_sh = shard_fn(self.mesh, state, probe)
            if uses_open:
                # o_idx is drawn inside the scan; the ctx argument omits it
                ctx_sh = dataclasses.replace(ctx_sh, o_idx=EMPTY)
            rep = NamedSharding(self.mesh, PartitionSpec())
            plan_sh = jax.tree.map(lambda _: rep, plan)
            kw["in_shardings"] = (state_sh, ctx_sh, None, plan_sh)
            # as in _build_round: the next chunk consumes this chunk's state
            kw["out_shardings"] = (state_sh, None, None)
        return jax.jit(chunk_fn, **kw)

    def _get_chunk(self, k: int, n_open: int, n_r: int, state: RoundState,
                   ctx0: BatchCtx, plan, overlap: bool = False):
        # `overlap` keys the cache: each schedule holds its own compiled
        # program, so toggling it between runs is a dict hit, not a
        # recompile (pinned by tests/test_overlap.py's JitCacheWatch)
        key = (k, n_open, n_r, overlap,
               jax.tree_util.tree_structure((state, ctx0, plan)))
        fn = self._chunk_cache.get(key)
        if fn is None:
            fn = self._chunk_cache[key] = self._build_chunk(
                k, n_open, n_r, state, ctx0, plan, overlap=overlap)
        return fn

    # ------------------------------------------------------------- setup ----
    def init(self, model_init: Callable, data, rng=None) -> RoundState:
        """Fresh-training entry point: also resets ``rounds_done`` and
        ``history`` so a reused engine doesn't fast-forward the new run's
        RNG stream past the previous training's rounds (resume goes through
        ``load_state``, which restores both instead)."""
        if rng is None:
            rng = jax.random.PRNGKey(self.algo.hp.seed)
        self.rounds_done = 0
        self.history = []
        return self.algo.init(rng, model_init, data)

    def make_ctx(self, data, o_idx=EMPTY, weights=EMPTY,
                 active_budget=None, cohort=EMPTY,
                 population=None) -> BatchCtx:
        open_x = data.open_x if self.algo.uses_open else EMPTY
        return BatchCtx(x=data.x_clients, y=data.y_clients,
                        open_x=open_x, o_idx=o_idx, weights=weights,
                        cohort=cohort, active_budget=active_budget,
                        population=population)

    # --------------------------------------------------------------- run ----
    def run(self, state: RoundState, data, rounds: Optional[int] = None,
            weights=EMPTY, log_every: int = 1,
            start_round: Optional[int] = None, chunk_rounds: int = 1,
            ctx_plan=None, active_budget: Optional[int] = None,
            cohort=EMPTY, population: Optional[int] = None,
            overlap: bool = False) -> RoundState:
        """Run ``rounds`` federated rounds starting at ``start_round``
        (default: ``self.rounds_done``, which ``load_state`` restores from a
        checkpoint).  The per-round RNG chain is fast-forwarded past the
        rounds already run, so a save/load/run sequence — or repeated
        ``run(rounds=1)`` calls on one engine — continues the exact key
        stream (and round numbering) an uninterrupted run would produce.

        ``chunk_rounds=k`` folds k rounds at a time into one compiled
        ``lax.scan`` (bitwise identical to the default per-round loop; see
        ``_build_chunk``).  With ``eval_fn`` set, chunk boundaries snap to
        ``log_every`` so every eval still sees the exact log-point state.
        The per-round host hooks (``on_round``/``on_ctx``) force the loop
        path — schedulers that can plan a whole chunk a priori pass
        ``ctx_plan`` instead: a dict of per-round BatchCtx field overrides
        (e.g. ``{"mask": (rounds, K), "stale": (rounds, K)}``) consumed by
        both paths.

        ``active_budget=m`` turns masked rounds participation-sparse: the
        algorithms compute only (at most) m gathered client lanes per round
        instead of the full K-stack — bitwise identical, ~K/m cheaper.  It
        is static (BatchCtx metadata), so it composes with ``chunk_rounds``
        and ``ctx_plan``; the caller guarantees every served mask has at
        most m participants (`repro.sim` schedulers do, by construction).

        ``cohort``/``population`` run the rounds cohort-resident: ``data``
        and ``state.clients`` carry an (S, ...) slab over the (S,) global
        ids in ``cohort``, and ``population`` is the true fleet size K used
        for per-client key derivation (see ``BatchCtx``).  The engine's own
        machinery — treedef-keyed round caches, fused scan, ctx plans,
        sparse budget — is oblivious to the distinction; the host-side
        slab orchestration lives in `repro.sim.runner.CohortRunner`.

        ``overlap=True`` runs the fused chunks on the software-pipelined
        schedule: each scan body finishes round r and immediately issues
        round r+1's logit exchange (``algo.round_start``), double-buffering
        the in-flight upload tensors so the cross-pod all-gather can hide
        behind the next round's private-data compute (see ``_build_chunk``).
        Bitwise identical to ``overlap=False`` — the pinned baseline — and
        requires the algorithm to expose the ``round_start``/``round_finish``
        halves; the per-round loop path has nothing to pipeline and falls
        back to the sequential round with a warning."""
        hp = self.algo.hp
        if overlap and getattr(self.algo, "round_start", None) is None:
            raise ValueError(
                f"overlap=True needs algorithm {self.algo.name!r} to expose "
                f"round_start/round_finish (the pipelined round halves); "
                f"{type(self.algo).__name__} has no round_start")
        rounds = hp.rounds if rounds is None else rounds
        start = self.rounds_done if start_round is None else start_round
        if ctx_plan is not None:
            for f, v in ctx_plan.items():
                if _leading_dim(v) < rounds:
                    # fail loudly on both paths: jnp's clamped indexing would
                    # silently reuse the last plan row on the loop path while
                    # lax.scan raised on the scanned one
                    raise ValueError(
                        f"ctx_plan[{f!r}] covers {_leading_dim(v)} rounds; "
                        f"run() needs {rounds}")
            mask_plan = ctx_plan.get("mask")
            if (active_budget is not None and mask_plan is not None
                    and active_budget < mask_plan.shape[-1]):
                # the sparse-round contract, enforced loudly while the plan
                # is still host-side: every round needs 1 <= participants <=
                # budget.  Overflow would silently skip clients that carry
                # aggregation weight; an empty round's aggregation falls
                # back to uniform-over-K, which needs the uploads the
                # sparse plane never computes.  Checked in numpy — the sim
                # path calls run() once per fused chunk, and device
                # reductions here would add blocking host syncs to a loop
                # whose whole point is one sync per chunk
                pops = (np.asarray(mask_plan) > 0).sum(axis=-1)
                lo, hi = int(pops.min()), int(pops.max())
                if lo < 1 or hi > active_budget:
                    raise ValueError(
                        f"active_budget={active_budget} needs 1 <= "
                        f"participants <= budget every round; ctx_plan "
                        f"masks have [{lo}, {hi}]")
        rng = jax.random.PRNGKey(hp.seed)
        if start:
            rng = _fast_forward_key(rng, start)
        if self.algo.uses_open:
            n_open = _leading_dim(data.open_x)
            n_r = min(hp.open_batch, n_open)
        else:
            n_open = n_r = 0
        chunk = self._effective_chunk(chunk_rounds)
        if chunk > 1:
            if self.eval_fn is not None and log_every < chunk:
                import warnings
                warnings.warn(
                    f"eval_fn snaps every scan segment to log_every="
                    f"{log_every} rounds, discarding most of the requested "
                    f"chunk_rounds={chunk} fusion (each eval needs a host "
                    f"sync); pass log_every=chunk_rounds to actually fuse",
                    stacklevel=2)
            return self._run_scanned(state, data, rounds, weights, log_every,
                                     start, rng, chunk, ctx_plan, n_open, n_r,
                                     active_budget, cohort, population,
                                     overlap)
        if overlap:
            import warnings
            warnings.warn(
                "overlap=True only pipelines the fused scan path; the "
                "per-round loop (chunk_rounds<=1, or per-round host hooks) "
                "has nothing to double-buffer and runs the sequential "
                "round — which is bitwise the same schedule anyway",
                stacklevel=2)
        fn = None
        for r in range(start, start + rounds):
            rng, rk, ri = jax.random.split(rng, 3)
            o_idx = (jax.random.choice(ri, n_open, (n_r,), replace=False)
                     if self.algo.uses_open else EMPTY)
            ctx = self.make_ctx(data, o_idx=o_idx, weights=weights,
                                active_budget=active_budget, cohort=cohort,
                                population=population)
            if ctx_plan is not None:
                ctx = dataclasses.replace(
                    ctx, **{f: v[r - start] for f, v in ctx_plan.items()})
            if self.on_ctx is not None:
                # externally-supplied client subsets: a `repro.sim` scheduler
                # (or any caller) rewrites the ctx — participation mask,
                # staleness, weights — before the jitted round sees it.
                # Only this hook can change the ctx *structure* round-to-
                # round, so only here is the cached round re-resolved (a
                # host-side pytree flatten) every round
                ctx = self.on_ctx(r, ctx)
                fn = self._get_round(state, ctx)
            elif fn is None:
                fn = self._get_round(state, ctx)
            with obs.span("engine.round", "engine", round=r):
                state, m = fn(state, ctx, rk)
                if self.on_round is not None:
                    state = self.on_round(r, state)
                self.last_metrics = m
                self.rounds_done = r + 1
                if self.on_chunk is not None:
                    self.on_chunk(self.rounds_done, state)
                if (r + 1) % log_every == 0:
                    rec = {"round": r + 1,
                           **{k: float(v) for k, v in m.items()
                              if jnp.ndim(v) == 0}}
                    if self.eval_fn is not None:
                        with obs.span("engine.eval", "engine"):
                            rec.update(self.eval_fn(
                                *self.algo.eval_params(state)))
                    self.history.append(rec)
        reg = obs.current_registry()
        if reg is not None:
            reg.counter("engine.rounds").inc(rounds)
        return state

    def _effective_chunk(self, chunk_rounds: int) -> int:
        """Clamp the requested chunk: the per-round host hooks (and a
        manually overridden ``_round``) cannot run inside a scan."""
        chunk = max(1, int(chunk_rounds))
        if (self.on_round is not None or self.on_ctx is not None
                or self._round is not None):
            return 1
        return chunk

    def _run_scanned(self, state, data, rounds, weights, log_every, start,
                     rng, chunk, ctx_plan, n_open, n_r, active_budget=None,
                     cohort=EMPTY, population=None,
                     overlap: bool = False) -> RoundState:
        import time
        r, end, n_chunks = start, start + rounds, 0
        while r < end:
            k = min(chunk, end - r)
            n_chunks += 1
            if self.eval_fn is not None:
                # eval needs the state at every log point: snap the segment
                # to end exactly on the next log boundary
                k = min(k, (r // log_every + 1) * log_every - r)
            plan = (None if ctx_plan is None else
                    {f: v[r - start:r - start + k]
                     for f, v in ctx_plan.items()})
            ctx0 = self.make_ctx(data, weights=weights,
                                 active_budget=active_budget, cohort=cohort,
                                 population=population)
            fn = self._get_chunk(k, n_open, n_r, state, ctx0, plan,
                                 overlap=overlap)
            # the span covers dispatch through the chunk's one host sync
            # (device_get below) — all instrumentation sits OUTSIDE the
            # compiled scan, so the fused path stays bitwise identical and
            # keeps its one-sync-per-chunk discipline
            t0 = time.perf_counter()
            with obs.span("engine.chunk", "engine", rounds=k, start_round=r,
                          overlap=overlap):
                state, rng, ms = fn(state, ctx0, rng, plan)
                self.last_metrics = {key: v[-1] for key, v in ms.items()}
                # one host sync per chunk: the stacked per-round scalars land
                # together instead of one float() device round-trip per round
                scalars = jax.device_get({key: v for key, v in ms.items()
                                          if jnp.ndim(v) == 1})
            self._note_chunk_time(overlap, k, time.perf_counter() - t0)
            for i in range(k):
                if (r + i + 1) % log_every != 0:
                    continue
                rec = {"round": r + i + 1,
                       **{key: float(v[i]) for key, v in scalars.items()}}
                if self.eval_fn is not None:   # i == k - 1 by the snap above
                    with obs.span("engine.eval", "engine"):
                        rec.update(self.eval_fn(
                            *self.algo.eval_params(state)))
                self.history.append(rec)
            r += k
            self.rounds_done = r
            if self.on_chunk is not None:
                self.on_chunk(self.rounds_done, state)
        reg = obs.current_registry()
        if reg is not None:
            reg.counter("engine.rounds").inc(rounds)
            reg.counter("engine.chunks").inc(n_chunks)
        return state

    def _note_chunk_time(self, overlap: bool, k: int, seconds: float) -> None:
        """Host-side schedule telemetry, sampled only at chunk boundaries so
        the compiled scan keeps its bitwise-parity and one-sync-per-chunk
        contracts.  Each chunk contributes one per-round wallclock sample
        to its schedule's bucket; once this engine has timed BOTH schedules
        the ``engine.comm_hidden_us`` gauge reports the per-round time the
        pipelined schedule hides (mean serialized - mean pipelined).  The
        pipelined path additionally marks its in-flight exchange with a
        ``wire.exchange`` instant — dispatch-side, since the all-gather
        itself retires inside the compiled chunk."""
        us = seconds * 1e6 / max(k, 1)
        self._round_us.setdefault(bool(overlap), []).append(us)
        if overlap:
            obs.instant("wire.exchange", "wire", inflight=True, rounds=k)
            obs.instant("overlap", "engine", rounds=k,
                        per_round_us=round(us, 3))
        reg = obs.current_registry()
        ser, pipe = self._round_us.get(False), self._round_us.get(True)
        if reg is not None and ser and pipe:
            hidden = sum(ser) / len(ser) - sum(pipe) / len(pipe)
            reg.gauge("engine.comm_hidden_us").set(round(hidden, 3))

    # -------------------------------------------------------- comm bytes ----
    def _payload_ctx(self, data) -> BatchCtx:
        if self.algo.uses_open:
            n_r = min(self.algo.hp.open_batch, _leading_dim(data.open_x))
            o_idx = jnp.zeros((n_r,), jnp.int32)
        else:
            o_idx = EMPTY
        return self.make_ctx(data, o_idx=o_idx)

    def measured_leg_bytes(self, state: RoundState, data) -> tuple[int, int]:
        """(uplink bytes per client, downlink broadcast bytes) measured on
        the actually-encoded payload pytree via ``eval_shape`` (free).  The
        legs differ under a per-leg `wire.AsymmetricCodec` (sparse upload,
        dense broadcast); the `repro.sim` clock charges each separately."""
        with obs.span("wire.measure", "wire",
                      codec=self.codec.name) as sp:
            ctx = self._payload_ctx(data)
            up = jax.eval_shape(
                lambda s, c: self.codec.encode_up(
                    self.algo.upload_payload(s, c)), state, ctx)
            down = jax.eval_shape(
                lambda s, c: self.codec.encode_down(
                    self.algo.upload_payload(s, c)), state, ctx)
            up_b, down_b = nbytes(up), nbytes(down)
            sp.set(up_bytes=up_b, down_bytes=down_b)
        return up_b, down_b

    def measured_round_bytes(self, state: RoundState, data,
                             n_clients: Optional[int] = None) -> int:
        """Per-round wire bytes of this algorithm under ``self.codec``,
        measured on the actually-encoded payload pytree (via ``eval_shape``,
        so it costs no compute): K client uploads + 1 multicast broadcast —
        the convention `comm.CommModel` uses (for symmetric codecs both legs
        encode identically, so this is payload * (K + 1) exactly)."""
        K = _leading_dim(data.x_clients) if n_clients is None else n_clients
        up, down = self.measured_leg_bytes(state, data)
        return up * K + down

    # ----------------------------------------------------------- telemetry --
    def compile_counts(self) -> dict:
        """Compiled-program accounting (`obs.engine_compile_counts`): how
        many round/chunk signatures this engine built and how many programs
        their jits compiled — after warmup each signature should hold at
        exactly one program (the serve-engine discipline, CI-pinned by
        ``benchmarks/obs_smoke.py``)."""
        from ..obs import engine_compile_counts
        return engine_compile_counts(self)

    # ------------------------------------------------------- checkpointing --
    def save_state(self, path: str, state: RoundState) -> None:
        import numpy as np
        leaves = jax.tree_util.tree_flatten(state)[0]
        tag = np.frombuffer(self.algo.name.encode(), dtype=np.uint8)
        hist = np.frombuffer(json.dumps(self.history, default=float).encode(),
                             dtype=np.uint8)
        save_pytree(path, {"algo": tag, "leaves": leaves,
                           "round": np.int64(self.rounds_done),
                           "history": hist})

    def load_state(self, path: str, like: RoundState,
                   shardings=None) -> RoundState:
        """Restore a state saved by ``save_state``.  ``like`` supplies the
        treedef (e.g. a freshly-inited state of the same algorithm);
        ``shardings`` (a pytree of `jax.sharding.Sharding` matching the
        state, e.g. from ``algo.shardings``) places each leaf directly onto
        its shards.  Also restores ``rounds_done`` and ``history`` so a
        subsequent ``run`` resumes the RNG stream where the checkpoint
        left off."""
        import numpy as np
        raw = load_pytree(path)
        tag = bytes(np.asarray(raw["algo"]).tobytes()).decode()
        if tag != self.algo.name:
            raise ValueError(f"checkpoint is for {tag!r}, "
                             f"engine runs {self.algo.name!r}")
        treedef = jax.tree_util.tree_structure(like)
        n_like = treedef.num_leaves
        if len(raw["leaves"]) != n_like:
            raise ValueError(
                f"checkpoint {path!r} holds {len(raw['leaves'])} leaves but "
                f"the engine's state has {n_like} — it was saved from a "
                f"different arch/config than this {self.algo.name!r} state")
        state = jax.tree_util.tree_unflatten(treedef, raw["leaves"])
        # fail HERE, naming the mismatched leaves, instead of later inside a
        # jitted round with an opaque XLA shape error
        assert_tree_compatible(like, state, what=f"checkpoint {path!r}")
        if shardings is not None:
            state = jax.tree.map(lambda a, s: jax.device_put(a, s),
                                 state, shardings)
        if "round" in raw:
            self.rounds_done = int(np.asarray(raw["round"]))
        if "history" in raw:
            self.history = json.loads(
                bytes(np.asarray(raw["history"]).tobytes()).decode())
        return state
