"""`FedEngine` — one algorithm-agnostic federated trainer.

Generalizes the seed `protocol.DSFLEngine` to any `FedAlgorithm`: jits the
algorithm's round once, samples the shared open batch o_r (when the
algorithm uses one), runs test-set eval through ``algo.eval_params``,
accumulates a scalar history, measures wire bytes through a `wire.Codec`,
and checkpoints the full typed `RoundState` with the msgpack backend.

RNG discipline matches the seed engine exactly (``rng, rk, ri =
split(rng, 3)`` per round; o_r drawn from ``ri``; the round keyed by
``rk``) so `DSFLAlgorithm` under this engine is bit-for-bit identical to
the reference `DSFLEngine` — asserted by ``tests/test_engine.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..checkpoint import load_pytree, save_pytree
from .algorithms import BatchCtx, EMPTY, FedAlgorithm, RoundState
# re-exported so new-API callers need only this module (the implementation
# lives with the reference engine)
from .protocol import make_eval_fn  # noqa: F401
from .wire import Codec, DenseF32Codec, nbytes


@dataclass
class FedEngine:
    """Python-level orchestration around ``jax.jit(algo.round)``.

    ``eval_fn(params, model_state) -> dict`` is called on
    ``algo.eval_params(state)`` every ``log_every`` rounds; its scalars join
    the round metrics in ``history``.  Non-scalar round metrics (e.g. FD's
    (C, C) global logit) are kept out of the history but exposed on
    ``last_metrics``.  ``on_round(r, state) -> state`` runs un-jitted
    between rounds (attack injection, LR rescheduling, ...)."""
    algo: FedAlgorithm
    eval_fn: Optional[Callable] = None
    codec: Codec = field(default_factory=DenseF32Codec)
    on_round: Optional[Callable] = None
    history: list = field(default_factory=list)
    last_metrics: dict = field(default_factory=dict)

    def __post_init__(self):
        self._round = jax.jit(self.algo.round)

    # ------------------------------------------------------------- setup ----
    def init(self, model_init: Callable, data, rng=None) -> RoundState:
        if rng is None:
            rng = jax.random.PRNGKey(self.algo.hp.seed)
        return self.algo.init(rng, model_init, data)

    def make_ctx(self, data, o_idx=EMPTY, weights=EMPTY) -> BatchCtx:
        open_x = data.open_x if self.algo.uses_open else EMPTY
        return BatchCtx(x=data.x_clients, y=data.y_clients,
                        open_x=open_x, o_idx=o_idx, weights=weights)

    # --------------------------------------------------------------- run ----
    def run(self, state: RoundState, data, rounds: Optional[int] = None,
            weights=EMPTY, log_every: int = 1,
            start_round: int = 0) -> RoundState:
        """Run ``rounds`` federated rounds starting at ``start_round``.

        To resume from a checkpoint, pass the number of rounds already run
        as ``start_round``: the per-round RNG chain is fast-forwarded past
        them, so a save/load/run sequence continues the exact key stream
        (and round numbering) an uninterrupted run would have produced."""
        hp = self.algo.hp
        rounds = hp.rounds if rounds is None else rounds
        rng = jax.random.PRNGKey(hp.seed)
        for _ in range(start_round):
            rng, _, _ = jax.random.split(rng, 3)
        if self.algo.uses_open:
            n_open = data.open_x.shape[0]
            n_r = min(hp.open_batch, n_open)
        for r in range(start_round, start_round + rounds):
            rng, rk, ri = jax.random.split(rng, 3)
            o_idx = (jax.random.choice(ri, n_open, (n_r,), replace=False)
                     if self.algo.uses_open else EMPTY)
            ctx = self.make_ctx(data, o_idx=o_idx, weights=weights)
            state, m = self._round(state, ctx, rk)
            if self.on_round is not None:
                state = self.on_round(r, state)
            self.last_metrics = m
            if (r + 1) % log_every == 0:
                rec = {"round": r + 1,
                       **{k: float(v) for k, v in m.items() if v.ndim == 0}}
                if self.eval_fn is not None:
                    rec.update(self.eval_fn(*self.algo.eval_params(state)))
                self.history.append(rec)
        return state

    # -------------------------------------------------------- comm bytes ----
    def measured_round_bytes(self, state: RoundState, data,
                             n_clients: Optional[int] = None) -> int:
        """Per-round wire bytes of this algorithm under ``self.codec``,
        measured on the actually-encoded payload pytree (via ``eval_shape``,
        so it costs no compute): K client uploads + 1 multicast broadcast of
        the same payload shape — the convention `comm.CommModel` uses."""
        K = data.x_clients.shape[0] if n_clients is None else n_clients
        if self.algo.uses_open:
            n_r = min(self.algo.hp.open_batch, data.open_x.shape[0])
            o_idx = jnp.zeros((n_r,), jnp.int32)
        else:
            o_idx = EMPTY
        ctx = self.make_ctx(data, o_idx=o_idx)
        enc = jax.eval_shape(
            lambda s, c: self.codec.encode(self.algo.upload_payload(s, c)),
            state, ctx)
        return nbytes(enc) * (K + 1)

    # ------------------------------------------------------- checkpointing --
    def save_state(self, path: str, state: RoundState) -> None:
        import numpy as np
        leaves = jax.tree_util.tree_flatten(state)[0]
        tag = np.frombuffer(self.algo.name.encode(), dtype=np.uint8)
        save_pytree(path, {"algo": tag, "leaves": leaves})

    def load_state(self, path: str, like: RoundState) -> RoundState:
        """Restore a state saved by ``save_state``.  ``like`` supplies the
        treedef (e.g. a freshly-inited state of the same algorithm)."""
        import numpy as np
        raw = load_pytree(path)
        tag = bytes(np.asarray(raw["algo"]).tobytes()).decode()
        if tag != self.algo.name:
            raise ValueError(f"checkpoint is for {tag!r}, "
                             f"engine runs {self.algo.name!r}")
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, raw["leaves"])
