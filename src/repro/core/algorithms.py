"""Unified `FedAlgorithm` API (the substrate every scenario plugs into).

Every federated protocol in the repo — DS-FL (paper Algorithm 1), FD
(Jeong et al. 2018) and FedAvg (McMahan et al. 2017) — exposes the same
two-method surface:

    state            = algo.init(rng, model_init, data)   # -> RoundState
    state, metrics   = algo.round(state, ctx, rng)        # one federated round

`RoundState` / `ClientState` / `ServerState` are frozen dataclasses
registered as JAX pytrees, so one `jax.jit(algo.round)` covers any
algorithm (see `repro.core.engine.FedEngine`) and replaces the positional
``wk, sk, ouk, odk, wg, sg, odg`` soup of the original per-protocol round
builders.  `BatchCtx` carries the per-round data (private stacks, open
batch indices, FedAvg weights) as a single pytree argument.

Algorithms additionally expose:

  * ``uses_open``                — whether the engine must sample o_r;
  * ``upload_payload(state, ctx)`` — the per-client wire payload of one
    round (per-sample logits for DS-FL, per-class logits for FD, the full
    parameter vector for FedAvg), which `repro.core.wire` codecs encode and
    measure against `comm.CommModel`'s analytic byte counts;
  * ``eval_params(state)``       — the (params, model_state) pair a test-set
    evaluation should score (server model for DS-FL/FedAvg, mean client
    model for FD, which has no server model).

RNG discipline mirrors the (fixed) reference `protocol.make_dsfl_round`
bit-for-bit: the DS-FL round splits its key into (update, client-distill,
corrupt, server-distill) so the golden-parity test in
``tests/test_engine.py`` can compare the two engines exactly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from ..optim import optimizers as opt_lib
from . import fd as fd_lib
from .aggregation import (aggregate, participation_weights, weighted_era,
                          weighted_sa)
from .client import LocalSpec, local_distill, local_update, predict_probs
from .fedavg import weighted_average
from .hierarchy import hierarchical_weighted_era, hierarchical_weighted_sa
from .losses import entropy, pinned_mean, pinned_sum
from .prng import split_take
from .protocol import DSFLConfig  # noqa: F401  (re-exported as part of the API)

EMPTY = ()   # absent pytree slot (contributes no leaves)


def _pytree_dataclass(cls=None, *, meta=()):
    """Register a frozen dataclass as a JAX pytree.  ``meta`` names fields
    that are *static* (part of the treedef, not traced leaves) — e.g.
    ``BatchCtx.active_budget``, which fixes array shapes and must therefore
    be a Python int at trace time.  A changed meta value changes the treedef,
    so `FedEngine`'s treedef-keyed jit caches recompile automatically."""
    def wrap(c):
        fields = [f.name for f in dataclasses.fields(c) if f.name not in meta]
        return jax.tree_util.register_dataclass(c, data_fields=fields,
                                                meta_fields=list(meta))
    return wrap(cls) if cls is not None else wrap


# --------------------------------------------------------------- states ------
@_pytree_dataclass
@dataclass(frozen=True)
class ClientState:
    """Per-client persistent state, stacked over the leading (K,) axis."""
    params: Any = EMPTY         # model parameters, leaves (K, ...)
    model_state: Any = EMPTY    # e.g. BatchNorm running stats
    opt_update: Any = EMPTY     # optimizer state of the "1. Update" loop
    opt_distill: Any = EMPTY    # optimizer state of the "6. Distillation" loop


@_pytree_dataclass
@dataclass(frozen=True)
class ServerState:
    """Global-model state held by the server (empty for FD)."""
    params: Any = EMPTY
    model_state: Any = EMPTY
    opt_distill: Any = EMPTY


@_pytree_dataclass
@dataclass(frozen=True)
class RoundState:
    clients: ClientState = ClientState()
    server: ServerState = ServerState()


@_pytree_dataclass(meta=("active_budget", "population"))
@dataclass(frozen=True)
class BatchCtx:
    """Per-round data context (a single pytree argument to ``round``).

    ``mask``/``stale`` are the partial-participation fields the `repro.sim`
    schedulers fill in: absent clients (mask 0) neither train nor contribute
    to aggregation that round, and stale contributions (an async client that
    last synced its global labels ``stale`` aggregations ago) are discounted
    by the algorithm's ``staleness_decay``.  Left EMPTY, the round is the
    exact bit-pinned full-participation path.

    ``active_budget`` is the participation-sparse compute budget: a *static*
    upper bound m on how many clients can be active in any round this ctx
    serves (pytree metadata, so shapes stay static and the round still fuses
    into the engine's ``lax.scan``).  When set below K alongside ``mask``,
    the algorithms gather the m active lanes out of the (K, ...) client
    stack, run update/predict/distill on only those, and scatter results
    back — a ~K/m per-round compute and activation-memory reduction that is
    **bitwise identical** to the dense masked round (padding lanes carry
    exactly zero aggregation weight).  ``None`` (default) keeps the dense
    path.  Contract: ``1 <= popcount(mask) <= active_budget`` — schedulers
    guarantee both by construction (`repro.sim.scheduler`; a zero-
    participant round's aggregation falls back to uniform-over-K, which
    needs the very uploads the sparse plane skips — `FedEngine.run` and
    `SimRunner` reject violating plans loudly).

    ``cohort``/``population`` are the cohort-resident round plane: when
    ``cohort`` carries an (S,) int array of *global client ids*, the leading
    client axis of every per-client field (``x``/``y``/``mask``/``stale``/
    the client stack in `RoundState`) is an O(m) **slab** over those ids
    rather than the full population — client state streams through a host-
    side `repro.core.cohort.ClientStore` between rounds, and resident
    memory stops depending on K entirely.  ``population`` (static metadata)
    is the true fleet size K: per-client RNG keys are derived as rows
    ``cohort`` of ``split(r, population)`` (`core.prng.split_take`, O(S)),
    so a client consumes bitwise the same key stream whichever slab lane it
    lands in — the invariant that makes small-K cohort-resident rounds
    bitwise identical to the dense masked rounds (tests/test_cohort.py)."""
    x: Any = EMPTY          # (K, I_k, ...) private inputs
    y: Any = EMPTY          # (K, I_k) private labels
    open_x: Any = EMPTY     # (I_o, ...) the full shared open set
    o_idx: Any = EMPTY      # (n,) this round's open-batch indices o_r
    weights: Any = EMPTY    # (K,) client dataset sizes (FedAvg Eq. 3)
    mask: Any = EMPTY       # (K,) 0/1 participation this round
    stale: Any = EMPTY      # (K,) rounds since each client last synced
    cohort: Any = EMPTY     # (S,) global client id of each slab lane
    active_budget: Optional[int] = None   # static per-round activity bound m
    population: Optional[int] = None      # static fleet size K (cohort mode)


# ------------------------------------------------------------- protocol ------
@runtime_checkable
class FedAlgorithm(Protocol):
    """The algorithm surface `FedEngine` drives.  ``hp`` must provide
    ``rounds`` and ``seed``; ``uses_open`` algorithms also ``open_batch``."""
    name: str
    uses_open: bool

    def init(self, rng, model_init: Callable, data) -> RoundState: ...

    def round(self, state: RoundState, ctx: BatchCtx,
              rng) -> tuple[RoundState, dict]: ...

    def upload_payload(self, state: RoundState, ctx: BatchCtx): ...

    def eval_params(self, state: RoundState): ...


def _stack_init(model_init: Callable, key, K: int):
    return jax.vmap(model_init)(jax.random.split(key, K))


def _first_client(tree):
    return jax.tree.map(lambda a: a[0], tree)


def present(slot) -> bool:
    """Whether an optional BatchCtx slot carries an array (EMPTY is ``()``).
    A Python-level (trace-time) predicate: ctx pytree structure is static
    under jit, so the masked and full-participation paths compile
    separately and the latter stays bit-identical to the seed round."""
    return not isinstance(slot, tuple)


def select_clients(mask, new_tree, old_tree):
    """Per-leaf ``where`` over the leading client axis: participants take the
    freshly-computed leaves, absent clients keep their previous state.
    Vectorized (one fused where per leaf, no per-client Python loop)."""
    m = mask.astype(bool)

    def sel(n, o):
        mb = m.reshape((m.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(mb, n, o)

    return jax.tree.map(sel, new_tree, old_tree)


def client_keys(rng, ctx: BatchCtx, K: int):
    """The (K, 2) per-client keys of one round leg.  Dense populations draw
    the house discipline's ``split(rng, K)``; a cohort slab draws rows
    ``ctx.cohort`` of ``split(rng, population)`` instead (O(S), bitwise the
    same rows — `core.prng.split_take`), so per-client randomness is a
    function of the *global* client id, never of slab placement."""
    if present(ctx.cohort):
        return split_take(rng, ctx.cohort, ctx.population)
    return jax.random.split(rng, K)


def masked_mean(values, mask):
    """Mean of ``values`` over the mask-1 lanes, reduction order pinned
    across programs (`losses.pinned_mean`): the dense masked round and the
    participation-sparse round are two different XLA programs reducing
    bitwise-identical (K,) inputs, and a plain fused reduce is free to
    reassociate differently in each — a dot-lowered sum is not."""
    return pinned_mean(values, mask.astype(jnp.float32))


# --------------------------------------------- participation-sparse plane ----
def active_indices(mask, budget: int):
    """Jit-safe gather indices for the participation-sparse round:
    (K,) mask -> (budget,) client indices.  A stable argsort over the 0/1
    activity key puts participants first *in ascending client order* and
    pads the remaining lanes with distinct non-participants — so a scatter
    back via ``.at[idx].set`` never collides, and padding lanes land on
    mask-0 clients whose results `select_clients` discards anyway.
    Requires ``budget >= popcount(mask)`` (the scheduler contract); with
    fewer lanes than participants, the overflow clients would silently keep
    stale state while still carrying aggregation weight."""
    key = jnp.where(mask > 0, jnp.int32(0), jnp.int32(1))
    return jnp.argsort(key, stable=True)[:budget]


def gather_clients(tree, idx):
    """Per-leaf gather of the ``idx`` lanes along the leading client axis:
    the (m, ...) active slice of a (K, ...) client stack."""
    return jax.tree.map(lambda a: jnp.take(a, idx, axis=0), tree)


def scatter_clients(new_tree, old_tree, idx):
    """Write the computed (m, ...) lanes back into the (K, ...) stack.
    ``idx`` lanes take the fresh leaves, all other clients keep their
    previous state — the sparse-plane counterpart of `select_clients`."""
    return jax.tree.map(lambda n, o: o.at[idx].set(n), new_tree, old_tree)


def scatter_zeros(values_m, K: int, idx):
    """Scatter (m, ...) per-lane results into an exact-zero (K, ...) buffer.
    The untouched lanes are *exactly* 0.0, so any downstream reduction that
    multiplies them by a zero participation weight is bitwise identical to
    the dense masked computation (0.0 * x == 0.0 == 0.0 * 0.0 for finite
    x) — the property the sparse round's bitwise-parity guarantee rides on."""
    return jnp.zeros((K,) + values_m.shape[1:], values_m.dtype
                     ).at[idx].set(values_m)


# ---------------------------------------------------------------- DS-FL ------
@dataclass(frozen=True)
class DSFLAlgorithm:
    """Paper Algorithm 1 on the unified API (SA / ERA / weighted-ERA).

    ``corrupt(probs (K, n, C), xo, rng) -> probs`` optionally injects
    malicious local logits between "2. Prediction" and "4. Aggregation".

    ``use_kernel=True`` routes "4. Aggregation" through the fused Pallas
    mean+sharpen kernels — including the *weighted* variant on the masked
    partial-participation (`repro.sim`) and weighted-ERA paths, which
    previously always fell back to einsum+softmax (two extra HBM passes
    over the (K, n, C) logit stack).  Default False: the pure-jnp route,
    bit-pinned against the seed engine.

    ``agg_edges > 1`` routes "4. Aggregation" through the two-level edge →
    server tree (`core.hierarchy`): globally-normalized weights, per-edge
    partial sums, server sharpen.  ``agg_edges=1`` (default) is bitwise the
    flat path; deeper trees carry `core.hierarchy`'s tolerance contract.
    """
    apply_fn: Callable
    hp: DSFLConfig
    corrupt: Optional[Callable] = None
    agg_weights: Optional[jax.Array] = None   # for aggregation="weighted_era"
    use_kernel: bool = False
    agg_edges: int = 1

    name = "dsfl"
    uses_open = True

    def _specs(self):
        hp = self.hp
        opt_u = opt_lib.make(hp.optimizer, hp.lr)
        opt_d = opt_lib.make(hp.optimizer, hp.lr_distill)
        spec_u = LocalSpec(self.apply_fn, opt_u, hp.local_epochs, hp.batch_size)
        spec_d = LocalSpec(self.apply_fn, opt_d, hp.distill_epochs,
                           min(hp.batch_size, hp.open_batch))
        return spec_u, spec_d

    def init(self, rng, model_init: Callable, data) -> RoundState:
        K = data.x_clients.shape[0]
        wg, sg = model_init(rng)
        wk, sk = _stack_init(model_init, rng, K)
        return self.init_from(wk, sk, wg, sg)

    def init_from(self, wk, sk, wg, sg) -> RoundState:
        """Build a RoundState around externally-initialized model params
        (the seed `DSFLEngine.init_states` contract)."""
        spec_u, spec_d = self._specs()
        return RoundState(
            clients=ClientState(params=wk, model_state=sk,
                                opt_update=jax.vmap(spec_u.opt.init)(wk),
                                opt_distill=jax.vmap(spec_d.opt.init)(wk)),
            server=ServerState(params=wg, model_state=sg,
                               opt_distill=spec_d.opt.init(wg)))

    def init_server(self, rng, model_init: Callable) -> RoundState:
        """Cohort-resident entry point: only the server model materializes
        (same ``rng`` discipline as `init`, so the server state is bitwise
        the dense init's); client slabs stream in via `init_cohort` /
        `repro.core.cohort.ClientStore`."""
        spec_u, spec_d = self._specs()
        wg, sg = model_init(rng)
        return RoundState(server=ServerState(params=wg, model_state=sg,
                                             opt_distill=spec_d.opt.init(wg)))

    def init_cohort(self, rng, model_init: Callable, ids,
                    population: int) -> ClientState:
        """The (|ids|, ...) slab of fresh client states for the given global
        ids: row g of the would-be dense `init` stack is re-derived from g's
        key alone (`core.prng.split_take`), so lazily materializing a
        million-client fleet m clients at a time is bitwise identical to
        gathering rows out of ``_stack_init(model_init, rng, K)``."""
        spec_u, spec_d = self._specs()
        wk, sk = jax.vmap(model_init)(split_take(rng, ids, population))
        return ClientState(params=wk, model_state=sk,
                           opt_update=jax.vmap(spec_u.opt.init)(wk),
                           opt_distill=jax.vmap(spec_d.opt.init)(wk))

    def _masked_teacher(self, probs, ctx: BatchCtx):
        """"3-5. Upload / Aggregation / Broadcast" of a masked round, over
        the full (K, n, C) upload stack.  Shared verbatim by the dense
        masked path and the participation-sparse path: the sparse plane
        scatters its computed prediction lanes into exact zeros, and every
        reduction here multiplies non-participant lanes by an exact-zero
        weight (``0.0 * x == 0.0`` for the finite probabilities crossing
        the wire) — which is what makes the two paths bitwise identical."""
        hp = self.hp
        agg_w = self.agg_weights
        if agg_w is None and hp.aggregation == "weighted_era":
            # adaptive reliability (paper §5 "future work"): inverse mean
            # entropy of each client's uploaded soft labels — absent lanes
            # get a finite garbage value that the mask zeroes exactly
            ent_k = jnp.mean(entropy(probs), axis=-1)           # (K,)
            agg_w = 1.0 / (ent_k + 1e-3)
        pw = participation_weights(
            ctx.mask, ctx.stale if present(ctx.stale) else None,
            hp.staleness_decay, base=agg_w)
        if self.agg_edges > 1:
            global_logit = (
                hierarchical_weighted_sa(probs, pw, self.agg_edges,
                                         use_kernel=self.use_kernel)
                if hp.aggregation == "sa"
                else hierarchical_weighted_era(probs, pw, hp.temperature,
                                               self.agg_edges,
                                               use_kernel=self.use_kernel))
        else:
            global_logit = (
                weighted_sa(probs, pw, use_kernel=self.use_kernel)
                if hp.aggregation == "sa"
                else weighted_era(probs, pw, hp.temperature,
                                  use_kernel=self.use_kernel))
        # the unsharpened SA diagnostic over the uploads that actually
        # happened: mask-weighted, since absent clients upload nothing
        sa_entropy = jnp.mean(entropy(weighted_sa(probs, ctx.mask)))
        return pw, global_logit, sa_entropy

    def round(self, state: RoundState, ctx: BatchCtx, rng):
        # the fused round IS the composition of its pipeline halves — the
        # same ops in the same order, split at the upload boundary — so the
        # engine's `overlap=True` scan (which issues `round_start` one body
        # early) is bitwise the sequential round by construction
        return self.round_finish(state, ctx,
                                 self.round_start(state, ctx, rng), rng)

    def _is_sparse(self, ctx: BatchCtx) -> bool:
        """Static predicate routing a round through the participation-sparse
        gather plane (`corrupt` sees the full upload stack, so it keeps the
        dense path — attack evaluation is not a perf path).  Shared by both
        halves so a split round can never disagree about its plane."""
        K = ctx.x.shape[0]
        return (present(ctx.mask) and ctx.active_budget is not None
                and ctx.active_budget < K and self.corrupt is None)

    def round_start(self, state: RoundState, ctx: BatchCtx, rng):
        """"1. Update" + "2. Prediction": everything up to (and including)
        the round's upload — the leg that depends only on the round's input
        state.  Returns the in-flight `(wk, sk, ouk, up_loss, probs)`
        buffers `round_finish` consumes (m-lane on the sparse plane).  Both
        halves draw the full ``split(rng, 4)`` so every sub-key lands on
        bitwise the fused round's consumer."""
        spec_u, _ = self._specs()
        wk, sk = state.clients.params, state.clients.model_state
        ouk = state.clients.opt_update
        K = ctx.x.shape[0]
        masked = present(ctx.mask)
        if self._is_sparse(ctx):
            return self._sparse_start(state, ctx, rng, ctx.active_budget)
        r1, _r2, r3, _r4 = jax.random.split(rng, 4)
        xo = jnp.take(ctx.open_x, ctx.o_idx, axis=0)

        # 1. Update (always computed for the full stack — a fused where keeps
        # absent clients' state; no per-client Python loop, shards cleanly)
        wk_n, sk_n, ouk_n, up_loss = jax.vmap(
            lambda w, s, o, xk, yk, rk: local_update(spec_u, w, s, o, xk, yk, rk)
        )(wk, sk, ouk, ctx.x, ctx.y, client_keys(r1, ctx, K))
        if masked:
            wk, sk, ouk = select_clients(ctx.mask, (wk_n, sk_n, ouk_n),
                                         (wk, sk, ouk))
        else:
            wk, sk, ouk = wk_n, sk_n, ouk_n

        # 2. Prediction (local logits on o_r)
        probs = jax.vmap(lambda w, s: predict_probs(self.apply_fn, w, s, xo)
                         )(wk, sk)
        if self.corrupt is not None:
            probs = self.corrupt(probs, xo, r3)
        return (wk, sk, ouk, up_loss, probs)

    def round_finish(self, state: RoundState, ctx: BatchCtx, inflight, rng):
        """"3-6'. Upload / Aggregation / Broadcast / Distillation": consume
        the in-flight upload buffers.  ``state`` supplies only what the
        start leg did not touch (distill optimizers + the server model)."""
        hp = self.hp
        _spec_u, spec_d = self._specs()
        odk = state.clients.opt_distill
        wg, sg = state.server.params, state.server.model_state
        odg = state.server.opt_distill
        K = ctx.x.shape[0]
        masked = present(ctx.mask)
        if self._is_sparse(ctx):
            return self._sparse_finish(state, ctx, inflight, rng,
                                       ctx.active_budget)
        _r1, r2, _r3, r4 = jax.random.split(rng, 4)
        xo = jnp.take(ctx.open_x, ctx.o_idx, axis=0)
        wk, sk, ouk, up_loss, probs = inflight

        # 3-5. Upload / Aggregation / Broadcast
        if masked:
            pw, global_logit, sa_entropy = self._masked_teacher(probs, ctx)
        else:
            agg_w = self.agg_weights
            if agg_w is None and hp.aggregation == "weighted_era":
                # adaptive reliability (paper §5 "future work"): inverse mean
                # entropy of each client's uploaded soft labels, re-estimated
                # every round — diffuse (unreliable) uploads get down-weighted
                ent_k = jnp.mean(entropy(probs), axis=-1)       # (K,)
                agg_w = 1.0 / (ent_k + 1e-3)
            pw = agg_w
            if self.agg_edges > 1:
                w = (jnp.ones((K,), jnp.float32) if agg_w is None else agg_w)
                global_logit = (
                    hierarchical_weighted_sa(probs, w, self.agg_edges,
                                             use_kernel=self.use_kernel)
                    if hp.aggregation == "sa"
                    else hierarchical_weighted_era(
                        probs, w, hp.temperature, self.agg_edges,
                        use_kernel=self.use_kernel))
            else:
                global_logit = aggregate(probs, hp.aggregation,
                                         hp.temperature, weights=agg_w,
                                         use_kernel=self.use_kernel)
            sa_entropy = jnp.mean(entropy(jnp.mean(probs, axis=0)))
        g_entropy = jnp.mean(entropy(global_logit))

        # 6. Distillation (clients, Eq. 10; absent clients keep their state)
        wk_n, sk_n, odk_n, d_loss = jax.vmap(
            lambda w, s, o, rk: local_distill(spec_d, w, s, o, xo,
                                              global_logit, rk)
        )(wk, sk, odk, client_keys(r2, ctx, K))
        if masked:
            wk, sk, odk = select_clients(ctx.mask, (wk_n, sk_n, odk_n),
                                         (wk, sk, odk))
        else:
            wk, sk, odk = wk_n, sk_n, odk_n

        # 6'. server global model (Eq. 11), with its own key r4
        wg, sg, odg, gd_loss = local_distill(spec_d, wg, sg, odg, xo,
                                             global_logit, r4)

        metrics = {"update_loss": (masked_mean(up_loss, ctx.mask) if masked
                                   else jnp.mean(up_loss)),
                   "distill_loss": (masked_mean(d_loss, ctx.mask) if masked
                                    else jnp.mean(d_loss)),
                   "server_distill_loss": gd_loss,
                   "global_entropy": g_entropy,
                   "sa_entropy": sa_entropy}
        if pw is not None:
            # normalized per-client aggregation weights (non-scalar: exposed
            # on `FedEngine.last_metrics`, kept out of the scalar history);
            # pinned total so the diagnostic agrees bitwise across the
            # dense-masked and sparse programs like every other reduction
            metrics["agg_weights"] = pw / jnp.maximum(pinned_sum(pw), 1e-9)
        if masked:
            metrics["participants"] = jnp.sum(ctx.mask.astype(jnp.float32))
        new = RoundState(
            clients=ClientState(wk, sk, ouk, odk),
            server=ServerState(wg, sg, odg))
        return new, metrics

    def _sparse_start(self, state: RoundState, ctx: BatchCtx, rng, m: int):
        """Participation-sparse start leg: gather the <= m active lanes of
        the client stack and run "1. Update" / "2. Prediction" vmapped over
        only the (m, ...) slice — ~K/m less client compute and activation
        memory, **bitwise identical** to the dense masked round (pinned by
        tests/test_engine_scan.py): per-client math sees the same inputs
        and the same per-client keys, and padding lanes carry exactly zero
        aggregation weight.  Returns the m-lane in-flight buffers; ``idx``
        is re-derived by the finish leg (a pure, cheap argsort), keeping
        the exchange buffers O(m)."""
        spec_u, _ = self._specs()
        wk, sk = state.clients.params, state.clients.model_state
        ouk = state.clients.opt_update
        K = ctx.x.shape[0]
        # identical key discipline to the dense round (r3 would feed
        # `corrupt`, which forces the dense path; split to keep key parity)
        r1, _r2, _r3, _r4 = jax.random.split(rng, 4)
        xo = jnp.take(ctx.open_x, ctx.o_idx, axis=0)

        idx = active_indices(ctx.mask, m)
        mask_m = jnp.take(ctx.mask, idx, axis=0)
        x_m, y_m = gather_clients((ctx.x, ctx.y), idx)
        wk_m, sk_m, ouk_m = gather_clients((wk, sk, ouk), idx)

        # 1. Update — only the gathered lanes; per-client keys gathered out
        # of the same (K,) split the dense round draws, so every active
        # client consumes bitwise its dense-path key
        wk_n, sk_n, ouk_n, up_loss = jax.vmap(
            lambda w, s, o, xk, yk, rk: local_update(spec_u, w, s, o, xk, yk,
                                                     rk)
        )(wk_m, sk_m, ouk_m, x_m, y_m,
          jnp.take(client_keys(r1, ctx, K), idx, axis=0))
        wk_m, sk_m, ouk_m = select_clients(mask_m, (wk_n, sk_n, ouk_n),
                                           (wk_m, sk_m, ouk_m))

        # 2. Prediction on the active lanes (the finish leg scatters into
        # exact zeros so the masked aggregation sees its (K, n, C) stack)
        probs_m = jax.vmap(lambda w, s: predict_probs(self.apply_fn, w, s, xo)
                           )(wk_m, sk_m)
        return (wk_m, sk_m, ouk_m, up_loss, probs_m)

    def _sparse_finish(self, state: RoundState, ctx: BatchCtx, inflight,
                       rng, m: int):
        """Participation-sparse finish leg: scatter the in-flight m-lane
        uploads into the shared masked aggregation, distill the gathered
        lanes, and scatter results back into the dense stacks."""
        _spec_u, spec_d = self._specs()
        wk, sk = state.clients.params, state.clients.model_state
        ouk, odk = state.clients.opt_update, state.clients.opt_distill
        wg, sg = state.server.params, state.server.model_state
        odg = state.server.opt_distill
        K = ctx.x.shape[0]
        _r1, r2, _r3, r4 = jax.random.split(rng, 4)
        xo = jnp.take(ctx.open_x, ctx.o_idx, axis=0)

        idx = active_indices(ctx.mask, m)
        mask_m = jnp.take(ctx.mask, idx, axis=0)
        odk_m = gather_clients(odk, idx)
        wk_m, sk_m, ouk_m, up_loss, probs_m = inflight
        probs = scatter_zeros(probs_m, K, idx)

        # 3-5. verbatim the dense masked aggregation on the scattered stack
        pw, global_logit, sa_entropy = self._masked_teacher(probs, ctx)
        g_entropy = jnp.mean(entropy(global_logit))

        # 6. Distillation (clients) on the gathered lanes
        wk_n, sk_n, odk_n, d_loss = jax.vmap(
            lambda w, s, o, rk: local_distill(spec_d, w, s, o, xo,
                                              global_logit, rk)
        )(wk_m, sk_m, odk_m, jnp.take(client_keys(r2, ctx, K), idx, axis=0))
        wk_m, sk_m, odk_m = select_clients(mask_m, (wk_n, sk_n, odk_n),
                                           (wk_m, sk_m, odk_m))

        # 6'. server global model (Eq. 11), with its own key r4
        wg, sg, odg, gd_loss = local_distill(spec_d, wg, sg, odg, xo,
                                             global_logit, r4)

        clients = ClientState(*scatter_clients(
            (wk_m, sk_m, ouk_m, odk_m), (wk, sk, ouk, odk), idx))
        metrics = {"update_loss": masked_mean(scatter_zeros(up_loss, K, idx),
                                              ctx.mask),
                   "distill_loss": masked_mean(scatter_zeros(d_loss, K, idx),
                                               ctx.mask),
                   "server_distill_loss": gd_loss,
                   "global_entropy": g_entropy,
                   "sa_entropy": sa_entropy,
                   "agg_weights": pw / jnp.maximum(pinned_sum(pw), 1e-9),
                   "participants": jnp.sum(ctx.mask.astype(jnp.float32))}
        return RoundState(clients=clients,
                          server=ServerState(wg, sg, odg)), metrics

    def upload_payload(self, state: RoundState, ctx: BatchCtx):
        """One client's upload: per-sample probability vectors on o_r."""
        xo = jnp.take(ctx.open_x, ctx.o_idx, axis=0)
        return predict_probs(self.apply_fn, _first_client(state.clients.params),
                             _first_client(state.clients.model_state), xo)

    def eval_params(self, state: RoundState):
        return state.server.params, state.server.model_state


# ------------------------------------------------------------------- FD ------
@dataclass(frozen=True)
class FDConfig:
    rounds: int = 30
    local_epochs: int = 5
    batch_size: int = 100
    lr: float = 0.1
    optimizer: str = "sgd"
    gamma: float = 1.0          # Eq. 7 distill regularizer weight
    n_classes: int = 10
    seed: int = 0


@dataclass(frozen=True)
class FDAlgorithm:
    """Federated Distillation benchmark (paper §2.2) on the unified API."""
    apply_fn: Callable
    hp: FDConfig

    name = "fd"
    uses_open = False

    def _spec(self):
        hp = self.hp
        return LocalSpec(self.apply_fn, opt_lib.make(hp.optimizer, hp.lr),
                         hp.local_epochs, hp.batch_size)

    def init(self, rng, model_init: Callable, data) -> RoundState:
        K = data.x_clients.shape[0]
        wk, sk = _stack_init(model_init, rng, K)
        return self.init_from(wk, sk)

    def init_from(self, wk, sk) -> RoundState:
        spec = self._spec()
        return RoundState(clients=ClientState(
            params=wk, model_state=sk,
            opt_update=jax.vmap(spec.opt.init)(wk)))

    def init_server(self, rng, model_init: Callable) -> RoundState:
        """FD has no server model: the cohort-resident round state starts
        empty and fills with streamed client slabs."""
        return RoundState()

    def init_cohort(self, rng, model_init: Callable, ids,
                    population: int) -> ClientState:
        """Fresh (|ids|, ...) client slab; bitwise rows of the dense `init`
        stack (see `DSFLAlgorithm.init_cohort`)."""
        spec = self._spec()
        wk, sk = jax.vmap(model_init)(split_take(rng, ids, population))
        return ClientState(params=wk, model_state=sk,
                           opt_update=jax.vmap(spec.opt.init)(wk))

    def round(self, state: RoundState, ctx: BatchCtx, rng):
        hp = self.hp
        spec = self._spec()
        wk, sk = state.clients.params, state.clients.model_state
        ok = state.clients.opt_update
        K = ctx.x.shape[0]
        masked = present(ctx.mask)
        if (masked and ctx.active_budget is not None
                and ctx.active_budget < K):
            return self._sparse_round(state, ctx, rng, ctx.active_budget)
        tk, owns = jax.vmap(
            lambda w, s, xk, yk: fd_lib.per_label_logits(
                self.apply_fn, w, s, xk, yk, hp.n_classes))(wk, sk, ctx.x, ctx.y)
        if masked:
            # absent clients' per-class tables leave the Eq. 5 mean entirely
            owns = jnp.logical_and(owns, ctx.mask.astype(bool)[:, None])
        tg, n_own = fd_lib.aggregate_fd(tk, owns)
        rngs = client_keys(rng, ctx, K)

        def per_client(w, s, o, xk, yk, tkk, rk):
            tgt = fd_lib.distill_targets(tg, tkk, n_own, yk)
            return local_update(spec, w, s, o, xk, yk, rk,
                                distill_extra=tgt, gamma=hp.gamma)

        wk_n, sk_n, ok_n, losses = jax.vmap(per_client)(wk, sk, ok, ctx.x,
                                                        ctx.y, tk, rngs)
        if masked:
            wk, sk, ok = select_clients(ctx.mask, (wk_n, sk_n, ok_n),
                                        (wk, sk, ok))
        else:
            wk, sk, ok = wk_n, sk_n, ok_n
        metrics = {"update_loss": (masked_mean(losses, ctx.mask) if masked
                                   else jnp.mean(losses)),
                   "global_logit": tg}        # (C, C), for Fig. 2 analysis
        return RoundState(clients=ClientState(wk, sk, ok)), metrics

    def _sparse_round(self, state: RoundState, ctx: BatchCtx, rng, m: int):
        """Participation-sparse FD round: per-class tables and the Eq. 7
        update run only on the <= m gathered active lanes; the Eq. 5 mean
        sees scattered zero tables whose ``owns`` rows are False — exactly
        the lanes the dense masked round multiplies by zero."""
        hp = self.hp
        spec = self._spec()
        wk, sk = state.clients.params, state.clients.model_state
        ok = state.clients.opt_update
        K = ctx.x.shape[0]
        idx = active_indices(ctx.mask, m)
        mask_m = jnp.take(ctx.mask, idx, axis=0)
        x_m, y_m = gather_clients((ctx.x, ctx.y), idx)
        wk_m, sk_m, ok_m = gather_clients((wk, sk, ok), idx)

        tk_m, owns_m = jax.vmap(
            lambda w, s, xk, yk: fd_lib.per_label_logits(
                self.apply_fn, w, s, xk, yk, hp.n_classes))(wk_m, sk_m,
                                                            x_m, y_m)
        owns_m = jnp.logical_and(owns_m, mask_m.astype(bool)[:, None])
        # non-gathered lanes scatter as (zeros, False): identical Eq. 5 terms
        # to the dense masked round's (finite table, False-by-mask) lanes
        tg, n_own = fd_lib.aggregate_fd(scatter_zeros(tk_m, K, idx),
                                        scatter_zeros(owns_m, K, idx))
        rngs_m = jnp.take(client_keys(rng, ctx, K), idx, axis=0)

        def per_client(w, s, o, xk, yk, tkk, rk):
            tgt = fd_lib.distill_targets(tg, tkk, n_own, yk)
            return local_update(spec, w, s, o, xk, yk, rk,
                                distill_extra=tgt, gamma=hp.gamma)

        wk_n, sk_n, ok_n, losses = jax.vmap(per_client)(wk_m, sk_m, ok_m,
                                                        x_m, y_m, tk_m, rngs_m)
        wk_m, sk_m, ok_m = select_clients(mask_m, (wk_n, sk_n, ok_n),
                                          (wk_m, sk_m, ok_m))
        wk, sk, ok = scatter_clients((wk_m, sk_m, ok_m), (wk, sk, ok), idx)
        metrics = {"update_loss": masked_mean(scatter_zeros(losses, K, idx),
                                              ctx.mask),
                   "global_logit": tg}
        return RoundState(clients=ClientState(wk, sk, ok)), metrics

    def upload_payload(self, state: RoundState, ctx: BatchCtx):
        """One client's upload: the per-class average logit table (C, C)."""
        t, _ = fd_lib.per_label_logits(
            self.apply_fn, _first_client(state.clients.params),
            _first_client(state.clients.model_state),
            ctx.x[0], ctx.y[0], self.hp.n_classes)
        return t

    def eval_params(self, state: RoundState):
        # FD has no server model: score the mean client model
        mean = lambda t: jax.tree.map(lambda a: jnp.mean(a, axis=0), t)
        return mean(state.clients.params), mean(state.clients.model_state)


# --------------------------------------------------------------- FedAvg ------
@dataclass(frozen=True)
class FedAvgConfig:
    rounds: int = 30
    local_epochs: int = 5
    batch_size: int = 100
    lr: float = 0.1
    optimizer: str = "sgd"
    staleness_decay: float = 0.5    # async: weight factor per round of lag
    seed: int = 0


@dataclass(frozen=True)
class FedAvgAlgorithm:
    """FedAvg benchmark (paper §2.1) on the unified API.  Client state is
    ephemeral (re-broadcast each round); only the server model persists."""
    apply_fn: Callable
    hp: FedAvgConfig

    name = "fedavg"
    uses_open = False

    def _spec(self):
        hp = self.hp
        return LocalSpec(self.apply_fn, opt_lib.make(hp.optimizer, hp.lr),
                         hp.local_epochs, hp.batch_size)

    def init(self, rng, model_init: Callable, data) -> RoundState:
        w0, s0 = model_init(rng)
        return self.init_from(w0, s0)

    def init_from(self, w0, s0) -> RoundState:
        return RoundState(server=ServerState(params=w0, model_state=s0))

    def round(self, state: RoundState, ctx: BatchCtx, rng):
        spec = self._spec()
        w0, s0 = state.server.params, state.server.model_state
        K = ctx.x.shape[0]
        masked = present(ctx.mask)
        sparse = (masked and ctx.active_budget is not None
                  and ctx.active_budget < K)

        def per_client(xk, yk, rk):
            opt_state = spec.opt.init(w0)
            return local_update(spec, w0, s0, opt_state, xk, yk, rk)

        if sparse:
            # client state is ephemeral: only the <= m active lanes train;
            # their results scatter into exact zeros, which the Eq. 3
            # weighted average multiplies by an exact-zero weight anyway
            idx = active_indices(ctx.mask, ctx.active_budget)
            x_m, y_m = gather_clients((ctx.x, ctx.y), idx)
            rngs_m = jnp.take(client_keys(rng, ctx, K), idx, axis=0)
            wk_m, sk_m, _, losses_m = jax.vmap(per_client)(x_m, y_m, rngs_m)
            wk = jax.tree.map(lambda a: scatter_zeros(a, K, idx), wk_m)
            sk = jax.tree.map(lambda a: scatter_zeros(a, K, idx), sk_m)
            losses = scatter_zeros(losses_m, K, idx)
        else:
            rngs = client_keys(rng, ctx, K)
            wk, sk, _, losses = jax.vmap(per_client)(ctx.x, ctx.y, rngs)
        weights = (jnp.ones((K,), jnp.float32)
                   if isinstance(ctx.weights, tuple) else ctx.weights)
        if masked:
            # absent clients carry exactly zero weight in the Eq. 3 average
            # (client state is ephemeral in FedAvg, so masking the average IS
            # the partial-participation round); stale async contributions are
            # discounted FedAsync-style
            weights = participation_weights(
                ctx.mask, ctx.stale if present(ctx.stale) else None,
                self.hp.staleness_decay, base=weights)
        new_w0 = weighted_average(wk, weights)
        new_s0 = weighted_average(sk, weights)
        metrics = {"update_loss": (masked_mean(losses, ctx.mask) if masked
                                   else jnp.mean(losses))}
        if masked:
            metrics["participants"] = jnp.sum(ctx.mask.astype(jnp.float32))
        return RoundState(server=ServerState(new_w0, new_s0)), metrics

    def upload_payload(self, state: RoundState, ctx: BatchCtx):
        """One client's upload: the full parameter vector (+ model state)."""
        return {"params": state.server.params,
                "model_state": state.server.model_state}

    def eval_params(self, state: RoundState):
        return state.server.params, state.server.model_state
