"""Pod-scale LLM DS-FL / FedAvg on the unified `FedAlgorithm` API.

`LLMDSFLAlgorithm` wraps `llm_dsfl.dsfl_round_step` (and `LLMFedAvgAlgorithm`
its `fedavg_round_step` benchmark twin) behind the same two-method surface
the smallnet algorithms use, so the sharded LLM path shares `FedEngine`:
typed `RoundState` holding the pod-stacked parameters, `BatchCtx` carrying
the private token stacks plus the shared open set (sub-sampled per round via
``o_idx``), msgpack checkpointing, measured wire bytes through the top-k
codec, and engine-side jit.

Each algorithm additionally exposes ``shardings(mesh, state, ctx)`` returning
(state, ctx) sharding pytrees built from `launch.sharding`'s name-based rules
with the federated-client axis on "pod" — `FedEngine(algo, mesh=...)` feeds
them to ``jax.jit(in_shardings=...)`` (with the state donated when
``donate_state=True``), which is exactly the placement the multi-pod dry-run
lowers.  On meshes without a "pod" axis the client axis stays replicated.

The wrappers are pinned bit-for-bit against the raw round steps in
tests/test_llm_algorithms.py, the LLM analogue of `tests/test_engine.py`'s
golden parity against `protocol.DSFLEngine`.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..models.base import ModelConfig
from .aggregation import participation_weights
from .algorithms import BatchCtx, ClientState, EMPTY, RoundState, present
from .llm_dsfl import (LLMDsflHP, dsfl_exchange, dsfl_round_finish,
                       dsfl_round_step, fedavg_round_step,
                       predict_open_probs)


def _participation(ctx: BatchCtx, decay: float):
    """(K,) aggregation weights from the sim's mask/stale ctx fields, or
    None for the exact full-participation path.  Shares the aggregation
    helper's all-zero fallback (decay 0 + all-stale cohort -> raw mask)."""
    if not present(ctx.mask):
        return None
    return participation_weights(
        ctx.mask, ctx.stale if present(ctx.stale) else None, decay)


def _take_open(open_x, o_idx):
    """Gather this round's open batch o_r out of the full shared open set."""
    return jax.tree.map(lambda a: jnp.take(a, o_idx, axis=0), open_x)


def _first_client(tree):
    return jax.tree.map(lambda a: a[0], tree)


def _mean_clients(tree):
    return jax.tree.map(
        lambda a: jnp.mean(a.astype(jnp.float32), axis=0).astype(a.dtype),
        tree)


def _stack_init(model_init, rng, data):
    K = jax.tree.leaves(data.x_clients)[0].shape[0]
    return jax.vmap(model_init)(jax.random.split(rng, K))


def _shardings(cfg: ModelConfig, mesh, state: RoundState, ctx: BatchCtx,
               with_open: bool):
    """(state, ctx) sharding pytrees: params P("pod", <tp/fsdp rules>),
    private batches P("pod", "data", ...), open set data-sharded, indices and
    the round key replicated."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from ..launch.sharding import batch_specs, param_specs, to_named

    client_axis = "pod" if "pod" in mesh.axis_names else None
    pshard = to_named(mesh, param_specs(cfg, state.clients.params, mesh,
                                        client_axis=client_axis))
    st = RoundState(clients=ClientState(params=pshard))
    xsh = to_named(mesh, batch_specs(ctx.x, mesh, client_axis=client_axis))
    rep = NamedSharding(mesh, P())
    # the sim's participation fields (tiny (K,) vectors) stay replicated;
    # mirrored only when present so the ctx treedefs match
    mask = rep if not isinstance(ctx.mask, tuple) else EMPTY
    stale = rep if not isinstance(ctx.stale, tuple) else EMPTY
    # active_budget is pytree *metadata*: it must mirror the real ctx's
    # value or the sharding pytree's treedef won't match the argument's
    budget = ctx.active_budget
    if with_open:
        osh = to_named(mesh, batch_specs(ctx.open_x, mesh))
        return st, BatchCtx(x=xsh, open_x=osh, o_idx=rep, mask=mask,
                            stale=stale, active_budget=budget)
    return st, BatchCtx(x=xsh, mask=mask, stale=stale, active_budget=budget)


@dataclass(frozen=True)
class LLMDSFLAlgorithm:
    """DS-FL at pod scale on the unified API: each federated client is one
    pod; the round's only cross-pod collective is the open-batch logit
    exchange (all-gather of top-k pairs under ``hp.topk``)."""
    cfg: ModelConfig
    hp: LLMDsflHP

    name = "llm_dsfl"
    uses_open = True

    def init(self, rng, model_init, data) -> RoundState:
        return self.init_from(_stack_init(model_init, rng, data))

    def init_from(self, stacked_params) -> RoundState:
        """Build a RoundState around externally-initialized pod-stacked
        params (leaves (n_clients, ...))."""
        return RoundState(clients=ClientState(params=stacked_params))

    def round(self, state: RoundState, ctx: BatchCtx, rng):
        del rng   # dsfl_round_step is deterministic given the batches
        open_b = _take_open(ctx.open_x, ctx.o_idx)
        new, loss = dsfl_round_step(
            self.cfg, state.clients.params, ctx.x, open_b, self.hp,
            weights=_participation(ctx, self.hp.staleness_decay),
            mask=ctx.mask if present(ctx.mask) else None,
            active_budget=ctx.active_budget)
        return RoundState(clients=ClientState(params=new)), {"loss": loss}

    # -- pipelined round halves (engine `overlap=True` path) ----------------
    # round == round_finish(state, ctx, round_start(state, ctx, rng), rng)
    # bitwise: the halves are the same ops in the same order, just split at
    # the wire boundary so the scan body can issue round r+1's exchange
    # before round r's compute leg retires.
    def round_start(self, state: RoundState, ctx: BatchCtx, rng):
        """Issue the round's WIRE leg: open-batch prediction + the cross-pod
        all-gather of the (compressed) uploads.  Returns the in-flight
        exchange buffers; depends only on the round's input params."""
        del rng   # dsfl_round_step is deterministic given the batches
        open_b = _take_open(ctx.open_x, ctx.o_idx)
        return dsfl_exchange(
            self.cfg, state.clients.params, open_b, self.hp,
            weights=_participation(ctx, self.hp.staleness_decay),
            mask=ctx.mask if present(ctx.mask) else None,
            active_budget=ctx.active_budget)

    def round_finish(self, state: RoundState, ctx: BatchCtx, inflight, rng):
        """Consume the in-flight exchange: aggregate the teacher and run the
        hybrid CE+KD client step (the leg whose private-data branch never
        touches ``inflight`` — the slack the wire hides behind)."""
        del rng
        open_b = _take_open(ctx.open_x, ctx.o_idx)
        new, loss = dsfl_round_finish(
            self.cfg, state.clients.params, ctx.x, open_b, inflight, self.hp,
            weights=_participation(ctx, self.hp.staleness_decay),
            mask=ctx.mask if present(ctx.mask) else None,
            active_budget=ctx.active_budget)
        return RoundState(clients=ClientState(params=new)), {"loss": loss}

    def upload_payload(self, state: RoundState, ctx: BatchCtx):
        """One client's upload: per-token class distributions on o_r —
        (|o_r|, S, V) bf16, the tensor the wire codec encodes."""
        open_b = _take_open(ctx.open_x, ctx.o_idx)
        return predict_open_probs(self.cfg, _first_client(state.clients.params),
                                  open_b)

    def eval_params(self, state: RoundState):
        # no server model at LLM scale: score the mean client model (cf. FD)
        return _mean_clients(state.clients.params), EMPTY

    def shardings(self, mesh, state: RoundState, ctx: BatchCtx):
        return _shardings(self.cfg, mesh, state, ctx, with_open=True)


@dataclass(frozen=True)
class LLMFedAvgHP:
    lr: float = 1e-4
    staleness_decay: float = 0.5    # async sim: weight factor per round of lag
    rounds: int = 10
    seed: int = 0


@dataclass(frozen=True)
class LLMFedAvgAlgorithm:
    """Benchmark 1 at pod scale: local SGD then a parameter mean over the pod
    axis — the all-reduce whose bytes equal the model size."""
    cfg: ModelConfig
    hp: LLMFedAvgHP

    name = "llm_fedavg"
    uses_open = False

    def init(self, rng, model_init, data) -> RoundState:
        return self.init_from(_stack_init(model_init, rng, data))

    def init_from(self, stacked_params) -> RoundState:
        return RoundState(clients=ClientState(params=stacked_params))

    def round(self, state: RoundState, ctx: BatchCtx, rng):
        del rng
        new, loss = fedavg_round_step(
            self.cfg, state.clients.params, ctx.x, self.hp.lr,
            weights=_participation(ctx, self.hp.staleness_decay),
            mask=ctx.mask if present(ctx.mask) else None,
            active_budget=ctx.active_budget)
        return RoundState(clients=ClientState(params=new)), {"loss": loss}

    def upload_payload(self, state: RoundState, ctx: BatchCtx):
        """One client's upload: its full parameter pytree."""
        return _first_client(state.clients.params)

    def eval_params(self, state: RoundState):
        # clients are synced by the round's broadcast: any one of them
        return _first_client(state.clients.params), EMPTY

    def shardings(self, mesh, state: RoundState, ctx: BatchCtx):
        return _shardings(self.cfg, mesh, state, ctx, with_open=False)
