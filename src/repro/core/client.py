"""Client-local training loops (jit/vmap-able building blocks).

A "client model" is any functional pair ``apply(params, state, x, train)``
-> ``(logits, new_state)`` (the smallnets API; LLM wrappers adapt to it).
All loops are pure ``lax.scan`` so a whole federated round jits as one XLA
program and ``jax.vmap`` lifts them over the client axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..optim.optimizers import Optimizer
from .losses import distill_xent, pinned_mean, softmax_xent, xent_int_labels


@dataclass(frozen=True)
class LocalSpec:
    apply_fn: Callable
    opt: Optimizer
    epochs: int
    batch_size: int


def _epoch_perm(key, n_items: int, batch_size: int) -> jax.Array:
    nb = n_items // batch_size
    return jax.random.permutation(key, n_items)[: nb * batch_size
                                                ].reshape(nb, batch_size)


def local_update(spec: LocalSpec, params, state, opt_state, x, y, rng,
                 distill_extra=None, gamma: float = 0.0):
    """E epochs of minibatch supervised training on one client's private data.
    ``distill_extra`` is an optional per-sample soft-target array ``(I, C)``
    aligned with ``x``; when given it adds the FD regularizer (Eq. 7):
    gamma * CE(distill targets) on the *private* inputs."""
    n = x.shape[0]
    # clamp like local_distill: batch_size > n would give zero batches per
    # epoch — an empty scan and jnp.mean over zero losses -> NaN metrics
    bs = min(spec.batch_size, n)

    def batch_step(carry, idx):
        params, st, ostate, step = carry
        xb = jnp.take(x, idx, axis=0)
        yb = jnp.take(y, idx, axis=0)

        def loss_fn(p, s):
            logits, ns = spec.apply_fn(p, s, xb, True)
            loss = xent_int_labels(logits, yb)
            if distill_extra is not None:
                tgt = jnp.take(distill_extra, idx, axis=0)
                loss = loss + gamma * softmax_xent(logits, tgt)
            return loss, ns

        (loss, ns), g = jax.value_and_grad(loss_fn, has_aux=True)(params, st)
        params, ostate = spec.opt.update(g, params, ostate, step)
        return (params, ns, ostate, step + 1), loss

    def epoch_step(carry, ekey):
        perm = _epoch_perm(ekey, n, bs)
        carry, losses = jax.lax.scan(batch_step, carry, perm)
        return carry, pinned_mean(losses)

    carry = (params, state, opt_state, jnp.int32(0))
    carry, losses = jax.lax.scan(epoch_step, carry,
                                 jax.random.split(rng, spec.epochs))
    params, state, opt_state, _ = carry
    return params, state, opt_state, pinned_mean(losses)


def local_distill(spec: LocalSpec, params, state, opt_state, x_open,
                  teacher_probs, rng):
    """DS-FL "6. Distillation" (Eq. 10): train on the open batch against the
    broadcast global logit."""
    n = x_open.shape[0]
    bs = min(spec.batch_size, n)

    def batch_step(carry, idx):
        params, st, ostate, step = carry
        xb = jnp.take(x_open, idx, axis=0)
        tb = jnp.take(teacher_probs, idx, axis=0)

        def loss_fn(p, s):
            logits, ns = spec.apply_fn(p, s, xb, True)
            return distill_xent(logits, tb), ns

        (loss, ns), g = jax.value_and_grad(loss_fn, has_aux=True)(params, st)
        params, ostate = spec.opt.update(g, params, ostate, step)
        return (params, ns, ostate, step + 1), loss

    def epoch_step(carry, ekey):
        perm = _epoch_perm(ekey, n, bs)
        carry, losses = jax.lax.scan(batch_step, carry, perm)
        return carry, pinned_mean(losses)

    carry = (params, state, opt_state, jnp.int32(0))
    carry, losses = jax.lax.scan(epoch_step, carry,
                                 jax.random.split(rng, spec.epochs))
    params, state, opt_state, _ = carry
    return params, state, opt_state, pinned_mean(losses)


def predict_probs(apply_fn: Callable, params, state, x, batch_size: int = 0):
    """Inference probabilities on the open batch ("2. Prediction", Eq. 9).

    ``batch_size > 0`` chunks the forward pass with ``lax.map`` so large open
    batches never materialize one giant activation set (the tail chunk is
    wrap-padded and the padding rows dropped)."""
    n = x.shape[0]
    if batch_size <= 0 or batch_size >= n:
        logits, _ = apply_fn(params, state, x, False)
        return jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    nb = -(-n // batch_size)
    pad = nb * batch_size - n
    if pad:
        x = jnp.concatenate([x, x[:pad]], axis=0)
    chunks = x.reshape((nb, batch_size) + x.shape[1:])

    def chunk_probs(xb):
        logits, _ = apply_fn(params, state, xb, False)
        return jax.nn.softmax(logits.astype(jnp.float32), axis=-1)

    probs = jax.lax.map(chunk_probs, chunks)
    return probs.reshape((nb * batch_size,) + probs.shape[2:])[:n]
