"""mamba2-2.7b — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from ..models.base import ModelConfig

ARCH_ID = "mamba2-2.7b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="ssm", n_layers=64, d_model=2560,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
        ssm_chunk=256, ssm_groups=1, tie_embeddings=True,
        source="arXiv:2405.21060")
