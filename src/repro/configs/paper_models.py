"""The paper's own four evaluation models (§4.1), exposed as configs so the
benchmark drivers can select them by id.  These use the smallnets substrate
(exact Keras-convention param counts; see models/smallnets.py)."""
from ..models.smallnets import make_smallnet

PAPER_MODELS = {
    "paper-mnist-cnn": dict(name="mnist_cnn"),
    "paper-fmnist-cnn": dict(name="fmnist_cnn"),
    "paper-imdb-lstm": dict(name="imdb_lstm"),
    "paper-reuters-dnn": dict(name="reuters_dnn"),
}


def make_paper_model(arch_id: str, **kw):
    spec = dict(PAPER_MODELS[arch_id])
    spec.update(kw)
    return make_smallnet(spec.pop("name"), **spec)
