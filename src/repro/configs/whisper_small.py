"""whisper-small — encoder-decoder, 12+12L; mel+conv frontend stubbed
(input_specs feeds 1500 precomputed frame embeddings). [arXiv:2212.04356]"""
from ..models.base import ModelConfig

ARCH_ID = "whisper-small"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="audio", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865,
        enc_layers=12, n_audio_frames=1500, act="gelu",
        pos_embed="learned", tie_embeddings=True,
        source="arXiv:2212.04356")
