"""phi3-medium-14b — dense RoPE SwiGLU GQA kv=10. [arXiv:2404.14219]"""
from ..models.base import ModelConfig

ARCH_ID = "phi3-medium-14b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense", n_layers=40, d_model=5120,
        n_heads=40, n_kv_heads=10, d_ff=17920, vocab=100352,
        act="swiglu",
        source="arXiv:2404.14219")
