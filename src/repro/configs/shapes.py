"""The four assigned input shapes.  Decode shapes lower ``serve_step`` (one
token against a seq_len cache); prefill lowers the DS-FL prediction pass;
train lowers the DS-FL hybrid train step."""
from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# window used when a full-attention arch runs long_500k (DESIGN.md §4)
LONG_CONTEXT_WINDOW = 8_192
