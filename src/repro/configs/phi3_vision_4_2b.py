"""phi-3-vision-4.2b — phi3-mini LM + CLIP patch-embed stub (576 patches).
[hf:microsoft/Phi-3-vision-128k-instruct]"""
from ..models.base import ModelConfig

ARCH_ID = "phi-3-vision-4.2b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="vlm", n_layers=32, d_model=3072,
        n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32064,
        n_patches=576, act="swiglu",
        source="hf:microsoft/Phi-3-vision-128k-instruct")
