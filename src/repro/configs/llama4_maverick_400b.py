"""llama4-maverick-400b-a17b — MoE 128 experts top-1, early fusion.
Dense and MoE layers alternate (that is what makes the 48L/128e/d_ff-8192
spec total ~400B rather than ~774B — matching the model card).
[hf:meta-llama/Llama-4-Scout-17B-16E family]"""
from ..models.base import ModelConfig

ARCH_ID = "llama4-maverick-400b-a17b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="moe", n_layers=48, d_model=5120,
        n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048,
        head_dim=128, n_experts=128, top_k=1, rope_theta=5e5,
        block_pattern=(("attn", "mlp"), ("attn", "moe")),
        source="hf:meta-llama/Llama-4-Scout-17B-16E")
