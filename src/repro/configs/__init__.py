"""Architecture registry: --arch <id> resolves here."""
from . import (gemma_7b, jamba_1_5_large_398b, llama4_maverick_400b,
               llama4_scout_17b, mamba2_2_7b, phi3_medium_14b,
               phi3_vision_4_2b, qwen1_5_110b, qwen1_5_4b, whisper_small)
from .shapes import LONG_CONTEXT_WINDOW, SHAPES, InputShape  # noqa

_MODULES = [qwen1_5_4b, mamba2_2_7b, qwen1_5_110b, jamba_1_5_large_398b,
            llama4_maverick_400b, llama4_scout_17b, phi3_vision_4_2b,
            gemma_7b, whisper_small, phi3_medium_14b]

ARCHS = {m.ARCH_ID: m.make_config for m in _MODULES}


def get_config(arch_id: str):
    return ARCHS[arch_id]()


def list_archs():
    return sorted(ARCHS)
