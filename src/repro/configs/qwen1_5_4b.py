"""qwen1.5-4b — dense 40L, GQA kv=20, QKV bias. [hf:Qwen/Qwen1.5-0.5B family]"""
from ..models.base import ModelConfig

ARCH_ID = "qwen1.5-4b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense", n_layers=40, d_model=2560,
        n_heads=20, n_kv_heads=20, d_ff=6912, vocab=151936,
        head_dim=128, qkv_bias=True, act="swiglu", rope_theta=1e6,
        source="hf:Qwen/Qwen1.5-0.5B")
