"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer. [arXiv:2403.19887]

TPU adaptation note (DESIGN.md §2): the Mamba sub-layers use the SSD mixer
with state 128 / head_dim 64 (MXU-aligned) rather than Mamba-1's N=16 scalar
recurrence, which has no efficient systolic mapping."""
from ..models.base import ModelConfig

ARCH_ID = "jamba-1.5-large-398b"

# one period: 8 sub-layers, attention at index 4, MoE every other FFN
PATTERN = (("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"),
           ("mamba", "moe"), ("attn", "mlp"), ("mamba", "moe"),
           ("mamba", "mlp"), ("mamba", "moe"))


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="hybrid", n_layers=72, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=24576, vocab=65536,
        head_dim=128, n_experts=16, top_k=2, block_pattern=PATTERN,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
        ssm_chunk=256, ssm_groups=8,
        source="arXiv:2403.19887")
