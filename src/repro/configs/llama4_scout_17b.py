"""llama4-scout-17b-a16e — MoE 16 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from ..models.base import ModelConfig

ARCH_ID = "llama4-scout-17b-a16e"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="moe", n_layers=48, d_model=5120,
        n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048,
        head_dim=128, n_experts=16, top_k=1, rope_theta=5e5,
        source="hf:meta-llama/Llama-4-Scout-17B-16E")
