"""qwen1.5-110b — dense 80L, GQA kv=8, QKV bias. [hf:Qwen/Qwen1.5-0.5B family]"""
from ..models.base import ModelConfig

ARCH_ID = "qwen1.5-110b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=49152, vocab=152064,
        head_dim=128, qkv_bias=True, act="swiglu", rope_theta=1e6,
        source="hf:Qwen/Qwen1.5-0.5B")
