"""gemma-7b — dense, GeGLU, head_dim=256 (16H MHA). [arXiv:2403.08295]"""
from ..models.base import ModelConfig

ARCH_ID = "gemma-7b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense", n_layers=28, d_model=3072,
        n_heads=16, n_kv_heads=16, d_ff=24576, vocab=256000,
        head_dim=256, act="geglu",
        source="arXiv:2403.08295")
