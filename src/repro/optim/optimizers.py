"""Minimal functional optimizers (no optax dependency).

API: ``opt = sgd(lr)``; ``state = init_opt(opt, params)``;
``params, state = opt.update(grads, params, state, step)``.
Paper settings: SGD lr=0.1 (image tasks), Adam lr=1e-3 (text tasks).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable          # (grads, params, state, step) -> (params, state)


def _lr_at(lr, step):
    return lr(step) if callable(lr) else lr


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def sgd(lr) -> Optimizer:
    def init(params):
        return ()

    def update(grads, params, state, step):
        s = _lr_at(lr, step)
        new = jax.tree.map(lambda p, g: p - (s * g).astype(p.dtype), params, grads)
        return new, state

    return Optimizer("sgd", init, update)


def momentum(lr, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, params, state, step):
        s = _lr_at(lr, step)
        vel = jax.tree.map(lambda v, g: beta * v + g.astype(jnp.float32),
                           state, grads)
        new = jax.tree.map(lambda p, v: p - (s * v).astype(p.dtype), params, vel)
        return new, vel

    return Optimizer("momentum", init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, params, state, step):
        s = _lr_at(lr, step)
        t = step + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        new = jax.tree.map(
            lambda p, m_, v_: p - (s * (m_ / bc1)
                                   / (jnp.sqrt(v_ / bc2) + eps)).astype(p.dtype),
            params, m, v)
        return new, {"m": m, "v": v}

    return Optimizer("adam", init, update)


def init_opt(opt: Optimizer, params):
    return opt.init(params)


def make(name: str, lr) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adam": adam}[name](lr)
