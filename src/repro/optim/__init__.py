from .optimizers import adam, init_opt, momentum, sgd, apply_updates  # noqa
from .schedules import constant, cosine, linear_warmup  # noqa
