"""Learning-rate schedules as step -> lr callables."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def linear_warmup(lr: float, warmup: int):
    def f(step):
        return jnp.float32(lr) * jnp.minimum(1.0, (step + 1) / warmup)
    return f


def cosine(lr: float, total: int, warmup: int = 0, floor: float = 0.0):
    def f(step):
        w = jnp.minimum(1.0, (step + 1) / max(warmup, 1)) if warmup else 1.0
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return jnp.float32(w * (floor + 0.5 * (lr - floor) * (1 + jnp.cos(jnp.pi * t))))
    return f
