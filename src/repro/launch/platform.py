"""Named platform presets: the XLA-flag / device-tier / x64 tuning plane.

ROADMAP Open item 4's enabling half, in the spirit of bayespec's
``elisa/util/config.py`` and olmax's run scripts (SNIPPETS 1-3): every
knob that changes what a measurement *means* — latency-hiding scheduler,
async collectives, triton fusion, fake-device tiers, x64 — lives in a
named, stampable `PlatformPreset` instead of ad-hoc ``XLA_FLAGS`` exports
scattered across shells and CI yaml.

    from repro.launch import platform as pf
    pf.apply("overlap-cpu8")        # BEFORE any jax computation
    ...                             # flags now govern backend init

Rules of engagement:

* ``apply`` must run before the first jax computation — XLA reads
  ``XLA_FLAGS`` once, at lazy backend init.  (A module-level ``import
  jax`` is safe; creating the first array/device is not.)  Applying
  after init warns loudly and still records the intent, so the
  provenance stamp never lies about what was *requested* vs *active*.
* Presets MERGE with the ambient ``XLA_FLAGS`` rather than clobbering
  it: CI sets ``--xla_force_host_platform_device_count=8`` globally, and
  a preset must compose with that.  When both the environment and the
  preset force a host device count, the environment wins (the outer
  environment knows its machine; the preset is a portable request).
* The GPU scheduling flags (``--xla_gpu_enable_latency_hiding_scheduler``
  and friends) are compiled into every XLA build's DebugOptions, so they
  parse on CPU too — a CPU run under the ``overlap`` preset records the
  request and the backend simply has no async stream to use.  The real
  hazard is version skew: a flag XLA has since *removed* is FATAL at
  backend init (``parse_flags_from_env`` aborts the process), which is
  why ``_OVERLAP_FLAGS`` is pinned to spellings the repo's pinned jaxlib
  knows.  Whether async collectives actually *fired* is a separate,
  measured fact: `async_collectives_in` inspects compiled HLO for
  start/done pairs, and `benchmarks/engine_bench.py`'s ``overlap``
  section records the answer next to the timings.

Every applied preset is exposed via `active()` and stamped into
`repro.obs.RunProvenance` (``platform_preset`` / ``xla_flags``), so a
``BENCH_*.json`` number can never be read apart from the flag set that
produced it.
"""
from __future__ import annotations

import os
import warnings
from dataclasses import dataclass
from typing import Optional, Union

# the SNIPPET-1 (bayespec) GPU tuning set, modernized for this jaxlib: the
# latency-hiding scheduler + pipelined collectives are what let an
# all-gather overlap compute (XLA dropped the older
# ``--xla_gpu_enable_async_*`` spellings, and an unknown flag is FATAL at
# backend init — parse_flags_from_env aborts — so this set is pinned to
# flags the pinned jaxlib actually knows); the triton fusions ride along
# for the softmax/gemm-heavy ERA path
_OVERLAP_FLAGS = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
    "--xla_gpu_enable_pipelined_all_gather=true",
    "--xla_gpu_enable_pipelined_collectives=true",
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
)

_FORCE_HOST = "--xla_force_host_platform_device_count"


@dataclass(frozen=True)
class PlatformPreset:
    """One named tuning configuration.  ``xla_flags`` merge into the
    environment; ``host_device_count`` requests an N-fake-device CPU tier
    (ignored when the ambient ``XLA_FLAGS`` already forces a count);
    ``x64`` toggles ``jax_enable_x64`` (None = leave untouched)."""
    name: str
    description: str
    xla_flags: tuple = ()
    host_device_count: Optional[int] = None
    x64: Optional[bool] = None


PRESETS = {
    "default": PlatformPreset(
        "default", "no tuning: whatever the ambient environment says"),
    "cpu8": PlatformPreset(
        "cpu8", "8 fake CPU devices: the multi-device CI tier "
        "(exercises pod-sharded collectives without an accelerator)",
        host_device_count=8),
    "overlap": PlatformPreset(
        "overlap", "latency-hiding scheduler + async all-gather/"
        "collectives + triton fusion (SNIPPET-1 bayespec set): lets the "
        "pipelined exchange actually hide behind compute off-CPU",
        xla_flags=_OVERLAP_FLAGS),
    "overlap-cpu8": PlatformPreset(
        "overlap-cpu8", "the overlap flag set on the 8-fake-device CPU "
        "tier — the configuration the BENCH_engine overlap section runs",
        xla_flags=_OVERLAP_FLAGS, host_device_count=8),
    "x64": PlatformPreset(
        "x64", "double-precision mode (olmax JAX_ENABLE_X64 idiom)",
        x64=True),
}

_active: Optional[PlatformPreset] = None


def names() -> list:
    return sorted(PRESETS)


def active() -> Optional[PlatformPreset]:
    """The preset applied in this process, if any (provenance reads it)."""
    return _active


def backend_initialized() -> bool:
    """Whether jax has already materialized a backend (after which
    XLA_FLAGS edits no longer take effect).  Defensive: absent internals
    report False rather than raising."""
    try:
        import jax
        backends = getattr(
            getattr(jax, "_src", None), "xla_bridge", None)
        if backends is not None:
            return bool(getattr(backends, "_backends", None))
    except Exception:
        pass
    return False


def apply(preset: Union[str, PlatformPreset]) -> PlatformPreset:
    """Merge ``preset`` into the process environment (and jax config) and
    record it as the active preset.  Idempotent for a given preset; call
    it at the TOP of an entry point, before any jax computation."""
    global _active
    if isinstance(preset, str):
        try:
            preset = PRESETS[preset]
        except KeyError:
            raise ValueError(
                f"unknown platform preset {preset!r}; "
                f"available: {', '.join(names())}") from None
    ambient = os.environ.get("XLA_FLAGS", "")
    merged = [f for f in ambient.split() if f]
    for flag in preset.xla_flags:
        if flag not in merged:
            merged.append(flag)
    if preset.host_device_count is not None:
        if not any(f.startswith(_FORCE_HOST) for f in merged):
            merged.append(f"{_FORCE_HOST}={preset.host_device_count}")
        # else: the ambient environment already forces a count — it wins
    new_flags = " ".join(merged)
    if new_flags != ambient:
        if backend_initialized():
            warnings.warn(
                f"platform preset {preset.name!r} applied after jax "
                f"backend init: XLA_FLAGS changes will NOT take effect "
                f"in this process (apply() must run first)", stacklevel=2)
        if new_flags:
            os.environ["XLA_FLAGS"] = new_flags
    if preset.x64 is not None:
        import jax
        jax.config.update("jax_enable_x64", bool(preset.x64))
    _active = preset
    return preset


def add_args(ap) -> None:
    """Install ``--platform-preset`` on an argparse parser (the launch
    drivers and benchmarks share this flag)."""
    ap.add_argument(
        "--platform-preset", default=None, choices=names(),
        metavar="NAME",
        help="named XLA/platform tuning preset applied before backend "
             "init (merges with ambient XLA_FLAGS; stamped into "
             "provenance): " + ", ".join(names()))


def from_args(args) -> Optional[PlatformPreset]:
    """Apply the preset named by ``--platform-preset``, if any.  Call at
    the top of ``main`` — before building engines or touching devices."""
    name = getattr(args, "platform_preset", None)
    return apply(name) if name else None


# ------------------------------------------------ did-the-scheduler-fire ----
_ASYNC_MARKERS = ("all-gather-start", "collective-permute-start",
                  "all-reduce-start")


def async_collectives_in(hlo_text: str) -> bool:
    """Whether compiled HLO contains async collective start/done pairs —
    the measurable trace of the latency-hiding scheduler actually
    splitting a collective so it can overlap compute.  On single-stream
    CPU backends this is False even under the ``overlap`` preset; the
    bench records the answer rather than assuming the flags worked."""
    return any(m in hlo_text for m in _ASYNC_MARKERS)
