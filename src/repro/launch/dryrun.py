import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  512 placeholder host devices back the production
# meshes; smoke tests and benches import other modules and see 1 device.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination with ShapeDtypeStruct inputs — no allocation — and record
memory/cost/collective analysis for EXPERIMENTS.md §Dry-run / §Roofline.

Methodology (see EXPERIMENTS.md §Dry-run for the two caveats that force it):
  * PROVE pass: the full config lowers + compiles with the layer stack under
    ``lax.scan`` — small HLO, fast SPMD partitioning; memory_analysis comes
    from this artifact (that is what must fit per chip).
  * COST pass: XLA's cost_analysis counts while-loop bodies ONCE, so scanned
    FLOPs are wrong by ~n_blocks.  We therefore compile the same architecture
    at 2 and 4 blocks with the scan unrolled (full width — sharding behaviour
    identical) and extrapolate:  per_block = (m4 - m2)/2;
    total = m2 - 2*per_block + n_blocks*per_block.  Exact for homogeneous
    stacks, which all ten architectures are (per pattern-repeat).

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import functools
import json
import time
import traceback

import jax

from ..configs import SHAPES, get_config, list_archs
from ..core.llm_dsfl import (LLMDsflHP, dsfl_client_step, dsfl_round_step,
                             predict_open_probs)
from ..models.api import model_decode_step
from ..models.shardctx import axis_ctx
from .mesh import make_production_mesh
from .roofline import Roofline, collective_bytes, model_flops_estimate
from .sharding import batch_specs, cache_specs, param_specs, to_named
from .specs import input_specs

SKIPS = {
    # (arch, shape): reason — documented in DESIGN.md §4
    ("whisper-small", "long_500k"):
        "enc-dec with 1.5k-frame encoder and absolute positions has no "
        "500k-token decode mode; windowed variant would be a degenerate port",
}

RESULTS_DIR = "experiments/dryrun"


def reduced(cfg, n_blocks: int):
    """Same architecture at full width with n_blocks pattern-repeats."""
    kw = {"n_layers": n_blocks * len(cfg.pattern)}
    if cfg.arch_type == "audio":
        kw["enc_layers"] = n_blocks
    return cfg.replace(**kw)


def build_step(cfg, shape, mesh, *, multi_pod: bool, topk: int | None = None,
               hp_kw: dict | None = None, unroll: bool = False,
               fsdp: bool = True):
    """Returns (jitted_fn, args, step_name, ecfg, batch_axes)."""
    n_clients = 2 if (multi_pod and shape.kind == "train") else 1
    spec = input_specs(cfg, shape, n_clients=n_clients, topk=topk)
    ecfg = spec["cfg"].replace(scan_unroll=unroll)
    hp = LLMDsflHP(topk=topk, **(hp_kw or {}))
    client_axis = "pod" if n_clients > 1 else None
    pspec = to_named(mesh, param_specs(ecfg, spec["params"], mesh,
                                       client_axis=client_axis, fsdp=fsdp))

    if shape.kind == "train":
        if n_clients > 1:
            fn = functools.partial(dsfl_round_step, ecfg, hp=hp)
            in_sh = (pspec,
                     to_named(mesh, batch_specs(spec["private"], mesh,
                                                client_axis="pod")),
                     to_named(mesh, batch_specs(spec["open"], mesh)))
            args = (spec["params"], spec["private"], spec["open"])
            name = "dsfl_round_step"
        else:
            fn = functools.partial(dsfl_client_step, ecfg, hp=hp)
            in_sh = (pspec,
                     to_named(mesh, batch_specs(spec["private"], mesh)),
                     to_named(mesh, batch_specs(spec["open"], mesh)),
                     to_named(mesh, batch_specs(spec["teacher"], mesh)))
            args = (spec["params"], spec["private"], spec["open"],
                    spec["teacher"])
            name = "dsfl_client_step"
    elif shape.kind == "prefill":
        fn = functools.partial(predict_open_probs, ecfg)
        in_sh = (pspec, to_named(mesh, batch_specs(spec["open"], mesh)))
        args = (spec["params"], spec["open"])
        name = "predict_open_probs"
    else:
        fn = functools.partial(model_decode_step, ecfg)
        cspec = to_named(mesh, cache_specs(ecfg, spec["cache"], mesh,
                                           shape.global_batch))
        tspec = to_named(mesh, batch_specs(
            {"token": spec["token"], "pos": spec["pos"]}, mesh))
        in_sh = (pspec, cspec, tspec["token"], tspec["pos"])
        args = (spec["params"], spec["cache"], spec["token"], spec["pos"])
        name = "serve_step"
    jitted = jax.jit(fn, in_shardings=in_sh)
    batch_axes = ("data",) if (n_clients > 1 or not multi_pod) \
        else ("pod", "data")
    return jitted, args, name, ecfg, batch_axes


def _compile(cfg, shape, mesh, multi_pod, topk, hp_kw, unroll, fsdp=True):
    jitted, args, name, ecfg, batch_axes = build_step(
        cfg, shape, mesh, multi_pod=multi_pod, topk=topk, hp_kw=hp_kw,
        unroll=unroll, fsdp=fsdp)
    t0 = time.time()
    with axis_ctx(mesh, batch_axes=batch_axes):
        lowered = jitted.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return compiled, name, ecfg, round(t1 - t0, 1), round(t2 - t1, 1)


def _measure(compiled):
    ca = compiled.cost_analysis()
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll, "coll_total": float(sum(coll.values()))}


def _extrapolate_n(ma: dict, mb: dict, na: int, nb: int,
                   n_blocks: int) -> dict:
    """Linear-in-blocks extrapolation from measurements at na and nb blocks."""
    out = {}
    span = nb - na
    for k in ("flops", "bytes", "coll_total"):
        pb = (mb[k] - ma[k]) / span
        out[k] = max(ma[k] - na * pb + n_blocks * pb, 0.0)
    coll = {}
    kinds = set(ma["coll"]) | set(mb["coll"])
    for kind in kinds:
        a, b = ma["coll"].get(kind, 0), mb["coll"].get(kind, 0)
        pb = (b - a) / span
        coll[kind] = max(a - na * pb + n_blocks * pb, 0.0)
    out["coll"] = coll
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            topk: int | None = None, hp_kw: dict | None = None,
            verbose: bool = True, tag: str = "", cost_pass: bool = True,
            cfg_mod=None, fsdp: bool = True) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    shape = SHAPES[shape_name]
    # resume: skip combos already recorded as ok/skipped
    done = os.path.join(RESULTS_DIR,
                        f"{arch}_{shape_name}_{mesh_name}{tag}.json")
    if os.path.exists(done):
        with open(done) as f:
            prev = json.load(f)
        if prev.get("status") in ("ok", "skipped") and \
                (prev.get("status") == "skipped" or not cost_pass
                 or "t_compute" in prev):
            if verbose:
                print(f"[SKIP-DONE] {arch} x {shape_name} x {mesh_name}",
                      flush=True)
            return prev
    if (arch, shape_name) in SKIPS:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": SKIPS[(arch, shape_name)]}
        _save(rec, tag)
        return rec
    cfg = get_config(arch)
    if cfg_mod is not None:
        cfg = cfg_mod(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        # ---- PROVE: full config, scanned; train uses grad accumulation ----
        # (the COST pass uses microbatches=1: total FLOPs are identical and
        # scan bodies are only counted once — see §Dry-run methodology; the
        # x8 FSDP re-gather traffic of accumulation is discussed in §Perf)
        hp_prove = dict(hp_kw or {})
        if shape.kind == "train":
            hp_prove.setdefault("microbatches", 8)
        compiled, step_name, ecfg, lower_s, compile_s = _compile(
            cfg, shape, mesh, multi_pod, topk, hp_prove, unroll=False,
            fsdp=fsdp)
        mem = compiled.memory_analysis()
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "step": step_name, "status": "ok",
               "lower_s": lower_s, "compile_s": compile_s,
               "memory_analysis": {
                   "argument_size": mem.argument_size_in_bytes,
                   "output_size": mem.output_size_in_bytes,
                   "temp_size": mem.temp_size_in_bytes,
                   "code_size": mem.generated_code_size_in_bytes}}
        # ---- COST: 2/4-block unrolled extrapolation (single-pod roofline) --
        if cost_pass:
            c2, *_ = _compile(reduced(cfg, 1), shape, mesh, multi_pod, topk,
                              hp_kw, unroll=True, fsdp=fsdp)
            c4, *_ = _compile(reduced(cfg, 2), shape, mesh, multi_pod, topk,
                              hp_kw, unroll=True, fsdp=fsdp)
            ext = _extrapolate_n(_measure(c2), _measure(c4), 1, 2,
                                 cfg.n_blocks)
            rl = Roofline.from_terms(
                arch=arch, shape=shape_name, mesh_name=mesh_name,
                step=step_name, flops=ext["flops"], bytes_accessed=ext["bytes"],
                coll=ext["coll"], n_devices=mesh.devices.size,
                model_flops=model_flops_estimate(ecfg, shape),
                mem=mem)
            rec.update(rl.to_dict())
            if verbose:
                per_dev_gb = (mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes) / 1e9
                print(f"[OK] {arch} x {shape_name} x {mesh_name} ({step_name})"
                      f" compile {compile_s}s | args+temp {per_dev_gb:.2f} GB/dev"
                      f" | t_comp {rl.t_compute*1e3:.1f}ms"
                      f" t_mem {rl.t_memory*1e3:.1f}ms"
                      f" t_coll {rl.t_collective*1e3:.1f}ms -> {rl.bottleneck}"
                      f" | useful {rl.useful_ratio:.2f}", flush=True)
        elif verbose:
            per_dev_gb = (mem.argument_size_in_bytes
                          + mem.temp_size_in_bytes) / 1e9
            print(f"[OK] {arch} x {shape_name} x {mesh_name} ({step_name}) "
                  f"compile {compile_s}s | args+temp {per_dev_gb:.2f} GB/dev",
                  flush=True)
    except Exception as e:  # noqa: BLE001 — dry-run failures are findings
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "fail", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} x {mesh_name}: "
                  f"{rec['error'][:300]}", flush=True)
    _save(rec, tag)
    return rec


def _save(rec: dict, tag: str = ""):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{tag}.json"
    with open(os.path.join(RESULTS_DIR, name.replace("/", "_")), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--topk", type=int, default=None,
                    help="sparsified logit exchange (beyond-paper opt)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-cost", action="store_true",
                    help="prove-only (skip the 2/4-block cost pass)")
    args = ap.parse_args()

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                # roofline cost pass only on the single-pod mesh (§Roofline)
                results.append(run_one(arch, shape, multi_pod=mp,
                                       topk=args.topk, tag=args.tag,
                                       cost_pass=(not args.no_cost) and not mp))
    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    print(f"\n{ok} ok / {sk} skipped / {len(results) - ok - sk} failed "
          f"of {len(results)}")


if __name__ == "__main__":
    main()
