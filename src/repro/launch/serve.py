"""Serving driver over `repro.serve`: continuous-batching greedy decode
with the ring-buffer KV cache / SSM state.  This is the substrate behind
the decode_32k / long_500k dry-run shapes; at smoke scale it runs
end-to-end on CPU.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --smoke \
      --batch 4 --prompt-len 32 --gen 16

The default path drives `repro.serve.ServeEngine` (slot-based continuous
batching: requests with different prompt lengths join and leave the decode
batch without recompiling).  ``--decode-chunk d`` folds d decode steps
into one fused dispatch (one host sync per chunk) and ``--batch-insert``
admits same-bucket request groups through one compiled batched prefill —
both token-identical to the step-at-a-time defaults.  ``--lockstep`` runs
the pre-subsystem whole-batch baseline — one prefill, all requests
decoding in lockstep — kept because tests pin ServeEngine token-identical
to it.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs
from ..models.api import (model_decode_step, model_init, model_prefill)
from ..obs import cli as obs_cli
from ..serve import AdmissionQueue, ServeEngine
from . import platform
from .train import extra_inputs


def serve(cfg, params, batch: dict, gen: int, seq_budget: int):
    """Lockstep greedy generation (whole batch prefilled and decoded
    together).  Returns (tokens (B, gen), per-step seconds); the first
    entry of the times list is the compile step — report on times[1:]."""
    B, S0 = batch["tokens"].shape
    prefill_j = jax.jit(lambda p, b: model_prefill(cfg, p, b, seq_budget))
    step_j = jax.jit(lambda p, c, t, pos: model_decode_step(cfg, p, c, t, pos))
    logits, cache = prefill_j(params, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out, times = [tok], []
    pos0 = S0 + (cfg.n_patches if cfg.arch_type == "vlm" else 0)
    for i in range(gen - 1):
        t0 = time.perf_counter()
        logits, cache = step_j(params, cache, tok, jnp.int32(pos0 + i))
        logits.block_until_ready()
        times.append(time.perf_counter() - t0)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, 1), times


def steady_ms_per_step(times) -> float:
    """Mean decode ms/step excluding the first (compile) step."""
    steady = times[1:] if len(times) > 1 else times
    return 1e3 * sum(steady) / max(len(steady), 1)


def serve_continuous(cfg, params, prompts, gen: int, seq_budget: int, *,
                     decode_chunk: int = 1, batch_insert: bool = False):
    """The same workload through the continuous-batching subsystem: each
    prompt is a request; slots = number of requests so everything is
    admitted immediately.  ``decode_chunk``/``batch_insert`` select the
    fused fast paths (token-identical to the defaults).  Returns
    (responses by id, list of (seconds, decode steps) per dispatch)."""
    engine = ServeEngine(cfg, params, slots=len(prompts),
                         seq_budget=seq_budget)
    queue = AdmissionQueue(buckets=engine.buckets)
    # one clock for the whole request lifecycle (arrival/admission/steps),
    # so the latency bookkeeping on Response is internally consistent
    t0 = time.perf_counter()
    for toks in prompts:
        queue.submit(toks, gen, now=time.perf_counter() - t0)
    if batch_insert:
        while True:
            reqs = queue.admit(time.perf_counter() - t0,
                               len(engine.free_slots()), group=True)
            if not reqs:
                break
            engine.insert_batch(reqs, time.perf_counter() - t0)
    else:
        for req in queue.admit(time.perf_counter() - t0,
                               len(engine.free_slots())):
            engine.insert(req, time.perf_counter() - t0)
    times = []
    while engine.n_active:
        before = engine.n_steps
        ts = time.perf_counter()
        engine.step(time.perf_counter() - t0, decode_chunk=decode_chunk)
        times.append((time.perf_counter() - ts, engine.n_steps - before))
    by_id = {r.id: r for r in engine.pop_completed()}
    return [by_id[i] for i in sorted(by_id)], times


def steady_ms_per_decode_step(timed_steps) -> float:
    """Mean decode ms per accounted step from ``serve_continuous`` timing
    pairs, excluding the first (compile) dispatch."""
    steady = timed_steps[1:] if len(timed_steps) > 1 else timed_steps
    n = sum(k for _, k in steady)
    return 1e3 * sum(dt for dt, _ in steady) / max(n, 1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lockstep", action="store_true",
                    help="pre-subsystem whole-batch baseline path")
    ap.add_argument("--decode-chunk", type=int, default=1,
                    help="fuse this many decode steps into one compiled "
                         "scan (one host sync per chunk; token-identical)")
    ap.add_argument("--batch-insert", action="store_true",
                    help="admit same-bucket request groups through one "
                         "compiled batched prefill (token-identical)")
    platform.add_args(ap)
    obs_cli.add_args(ap)
    args = ap.parse_args(argv)
    # preset before backend init: XLA_FLAGS are read once
    platform.from_args(args)
    with obs_cli.session(args):
        run(args)


def run(args):
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(args.seed)
    params = model_init(cfg, key)
    kt, ke = jax.random.split(key)
    tokens = jax.random.randint(
        kt, (args.batch, args.prompt_len), 0, cfg.vocab, jnp.int32)
    seq_budget = args.prompt_len + args.gen + \
        (cfg.n_patches if cfg.arch_type == "vlm" else 0)

    if args.lockstep or cfg.arch_type in ("vlm", "audio"):
        # modality archs need per-request frames/patches the slot engine
        # doesn't carry yet — they stay on the lockstep path
        batch = {"tokens": tokens}
        batch.update(extra_inputs(cfg, args.batch, ke))
        toks, times = serve(cfg, params, batch, args.gen, seq_budget)
        print(f"[lockstep] generated {toks.shape} tokens; "
              f"decode {steady_ms_per_step(times):.1f} ms/step")
        print(toks[0])
        return

    prompts = [tuple(int(t) for t in row) for row in jax.device_get(tokens)]
    responses, times = serve_continuous(
        cfg, params, prompts, args.gen, seq_budget,
        decode_chunk=args.decode_chunk, batch_insert=args.batch_insert)
    n_tok = sum(len(r.tokens) for r in responses)
    print(f"[continuous] {len(responses)} requests, {n_tok} tokens; "
          f"decode {steady_ms_per_decode_step(times):.1f} ms/step over "
          f"{len(times)} dispatches (chunk={args.decode_chunk}, "
          f"batch_insert={args.batch_insert}, "
          f"weights v{responses[0].weights_version})")
    print(jnp.asarray(responses[0].tokens))


if __name__ == "__main__":
    main()
