"""Batched serving driver: prefill + greedy decode with the ring-buffer KV
cache / SSM state.  This is the substrate behind the decode_32k / long_500k
dry-run shapes; at smoke scale it runs end-to-end on CPU.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs
from ..models.api import (model_decode_step, model_init, model_prefill)
from .train import extra_inputs


def serve(cfg, params, batch: dict, gen: int, seq_budget: int):
    """Greedy generation. Returns (tokens (B, gen), per-step seconds)."""
    B, S0 = batch["tokens"].shape
    prefill_j = jax.jit(lambda p, b: model_prefill(cfg, p, b, seq_budget))
    step_j = jax.jit(lambda p, c, t, pos: model_decode_step(cfg, p, c, t, pos))
    logits, cache = prefill_j(params, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out, times = [tok], []
    pos0 = S0 + (cfg.n_patches if cfg.arch_type == "vlm" else 0)
    for i in range(gen - 1):
        t0 = time.time()
        logits, cache = step_j(params, cache, tok, jnp.int32(pos0 + i))
        logits.block_until_ready()
        times.append(time.time() - t0)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, 1), times


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(args.seed)
    params = model_init(cfg, key)
    kt, ke = jax.random.split(key)
    batch = {"tokens": jax.random.randint(
        kt, (args.batch, args.prompt_len), 0, cfg.vocab, jnp.int32)}
    batch.update(extra_inputs(cfg, args.batch, ke))
    seq_budget = args.prompt_len + args.gen + \
        (cfg.n_patches if cfg.arch_type == "vlm" else 0)
    toks, times = serve(cfg, params, batch, args.gen, seq_budget)
    print(f"generated {toks.shape} tokens; "
          f"decode {1e3 * sum(times) / max(len(times), 1):.1f} ms/step")
    print(toks[0])


if __name__ == "__main__":
    main()
