"""Per-leaf PartitionSpec rules: Megatron TP over "model", FSDP over "data",
expert parallelism over "model", and the DS-FL federated-client axis "pod".

Rules are name-based (the param tree uses stable leaf names) with divisibility
guards: a dim is sharded over an axis only when evenly divisible — GSPMD
uneven sharding of jit arguments is rejected (verified in this container), so
non-divisible dims fall back to replication.  Head counts not divisible by the
model-axis width (qwen1.5-4b: 20, llama4: 40, phi3-medium: 40, whisper: 12)
leave attention head-replicated on the TP axis; the §Perf log quantifies and
addresses this.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.base import ModelConfig


def _ax(mesh, name):
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _ok(dim_size: int, axis_size: int) -> bool:
    return axis_size > 1 and dim_size % axis_size == 0 and dim_size >= axis_size


class Ruler:
    def __init__(self, cfg: ModelConfig, mesh, fsdp: bool = True):
        self.cfg = cfg
        self.d = _ax(mesh, "data") if fsdp else 1
        self.m = _ax(mesh, "model")
        c = cfg
        self.q_tp = _ok(c.eff_heads, self.m) if c.n_heads else False
        self.kv_tp = _ok(c.eff_kv_heads, self.m) if c.n_kv_heads else False
        # attention TP only when BOTH q and kv heads split evenly (GQA groups
        # must stay aligned to shards)
        self.attn_tp = self.q_tp and self.kv_tp

    def D(self, n):     # FSDP data-axis candidate
        return "data" if _ok(n, self.d) else None

    def M(self, n):     # TP model-axis candidate
        return "model" if _ok(n, self.m) else None

    def leaf(self, name: str, shape: tuple[int, ...]):
        c = self.cfg
        s = shape
        if name == "tok":
            return P(self.M(s[0]), self.D(s[1]))
        if name == "unembed":
            return P(self.D(s[0]), self.M(s[1]))
        if name in ("wq", "wk", "wv"):
            tp = self.M(s[1]) if self.attn_tp else None
            return P(self.D(s[0]) if tp else self.D(s[0]), tp)
        if name in ("bq", "bk", "bv"):
            return P(self.M(s[0]) if self.attn_tp else None)
        if name == "wo":
            tp = self.M(s[0]) if self.attn_tp else None
            return P(tp, self.D(s[1]))
        if name in ("w_gate", "w_up"):
            if len(s) == 3:      # MoE (E, D, F): expert parallel
                return P(self.M(s[0]), self.D(s[1]), None)
            return P(self.D(s[0]), self.M(s[1]))
        if name == "w_down":
            if len(s) == 3:      # (E, F, D)
                return P(self.M(s[0]), self.D(s[1]), None)
            return P(self.M(s[0]), self.D(s[1]))
        if name == "b_up":
            return P(self.M(s[0]))
        if name == "router":
            return P(None, None)
        if name in ("w_z", "w_x", "w_b", "w_c", "w_dt"):
            return P(self.D(s[0]), self.M(s[1]))
        if name in ("cw_x", "cw_b", "cw_c"):
            return P(None, self.M(s[1]))
        if name in ("cb_x", "cb_b", "cb_c", "norm_scale"):
            return P(self.M(s[0]))
        if name in ("dt_bias", "a_log", "d_skip"):
            return P(self.M(s[0]))
        if name == "w_out":
            return P(self.M(s[0]), self.D(s[1]))
        if name == "pos_dec":
            return P(None, self.D(s[1]))
        if name == "w" and len(s) == 2:          # patch projector
            return P(self.D(s[0]), self.M(s[1]))
        return P(*([None] * len(s)))             # norms, biases, misc


_STACK_KEYS = ("blocks", "enc", "dec")


def param_specs(cfg: ModelConfig, params, mesh, client_axis: str | None = None,
                fsdp: bool = True):
    """PartitionSpec pytree matching ``params`` (a tree of arrays or
    ShapeDtypeStructs).  client_axis="pod" handles client-stacked leaves with
    an extra leading axis sharded over pods.  ``fsdp=False`` keeps params
    TP-only (serving mode: no per-step weight all-gathers)."""
    r = Ruler(cfg, mesh, fsdp=fsdp)

    def rule(path, leaf):
        keys = [k.key for k in path if hasattr(k, "key")]
        name = keys[-1]
        shape = tuple(leaf.shape)
        extra = 0
        if client_axis is not None:
            extra += 1
        stacked = any(k in _STACK_KEYS for k in keys)
        if stacked:
            extra += 1
        spec = r.leaf(name, shape[extra:])
        lead = ()
        if client_axis is not None:
            lead += (client_axis,)
        if stacked:
            lead += (None,)
        return P(*lead, *spec)

    return jax.tree_util.tree_map_with_path(rule, params)


def cache_specs(cfg: ModelConfig, cache, mesh, batch: int,
                client_axis: str | None = None):
    """Decode-cache shardings: batch over "data" when divisible; KV heads over
    "model" when divisible, else the cache sequence dim over the spare axes
    (long-context batch=1 decode shards the 500k ring buffer itself)."""
    r = Ruler(cfg, mesh)
    b_ax = "data" if _ok(batch, r.d) else None

    def rule(path, leaf):
        keys = [k.key for k in path if hasattr(k, "key")]
        name = keys[-1]
        s = tuple(leaf.shape)
        lead = (client_axis,) if client_axis else ()
        # stacked leading n_blocks/L axis is s[0]
        if name in ("k", "v", "cross_k", "cross_v"):
            # (L, B, W, Kh, hd)
            kh_ax = "model" if _ok(s[3], r.m) else None
            w_candidates = []
            if b_ax is None and _ok(s[2], r.d):
                w_candidates.append("data")
            if kh_ax is None and _ok(s[2], r.m):
                w_candidates.append("model")
            w_ax = tuple(w_candidates) if w_candidates else None
            return P(*lead, None, b_ax, w_ax, kh_ax, None)
        if name == "state":      # (L, B, H, P, N)
            return P(*lead, None, b_ax, "model" if _ok(s[2], r.m) else None,
                     None, None)
        if name in ("conv_x", "conv_b", "conv_c"):   # (L, B, w-1, C)
            return P(*lead, None, b_ax, None,
                     "model" if _ok(s[3], r.m) else None)
        return P(*([None] * len(s)))

    return jax.tree_util.tree_map_with_path(rule, cache)


def batch_specs(batch_tree, mesh, client_axis: str | None = None,
                vocab_axis_on: str = "model"):
    """Input batch shardings: batch dim over ("pod","data") as divisible;
    a trailing vocab-sized dim (teacher probs) over "model"."""
    r_d = _ax(mesh, "data")
    r_p = _ax(mesh, "pod") if client_axis is None else 1
    r_m = _ax(mesh, "model")

    def rule(path, leaf):
        s = tuple(leaf.shape)
        lead = (client_axis,) if client_axis else ()
        off = 1 if client_axis else 0
        if len(s) == off:       # scalar (pos)
            return P(*lead)
        b = s[off]
        baxes = []
        if client_axis is None and r_p > 1 and b % (r_p * r_d) == 0:
            baxes = ["pod", "data"]
        elif _ok(b, r_d):
            baxes = ["data"]
        spec = [tuple(baxes) if baxes else None]
        for dim in s[off + 1:-1]:
            spec.append(None)
        if len(s) > off + 1:
            last = s[-1]
            spec.append(vocab_axis_on if (last > 1024 and _ok(last, r_m))
                        else None)
        return P(*lead, *spec)

    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def to_named(mesh, spec_tree):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
