"""Production meshes.  Functions, not module-level constants — importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def _mk(shape, axes):
    # jax < 0.5 has no jax.sharding.AxisType (axes are implicitly Auto)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (TPU v5e pod).
    Multi-pod: 2x16x16 = 512 chips; the leading "pod" axis doubles as the
    DS-FL federated-client axis (DESIGN.md §5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_client_mesh(n_clients: int):
    """("pod", "data", "model") mesh over however many real devices exist,
    with the federated-client axis on "pod" when the device count divides
    (the 8-fake-device CI tier; collapses to (1, 1, n) on one device)."""
    n = len(jax.devices())
    pod = n_clients if n >= n_clients and n % n_clients == 0 else 1
    return _mk((pod, 1, n // pod), ("pod", "data", "model"))


def make_smoke_mesh(*, multi_pod: bool = False):
    """Same axis names on however many real devices exist (CPU tests)."""
    n = len(jax.devices())
    shape = (1, 1, n) if multi_pod else (1, n)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)
