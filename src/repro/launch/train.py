"""Training driver.

Two modes:
  * ``--mode dsfl``   - the paper's protocol at LLM scale: K simulated clients
    (vmapped; on the multi-pod mesh the client axis shards over pods), logit
    exchange on a shared open batch, ERA aggregation, hybrid CE+KD local steps.
  * ``--mode local``  - plain LM pretraining (the "1. Update" benchmark).

On this CPU container use ``--smoke`` (reduced config).  Example:

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --smoke \
      --mode dsfl --clients 2 --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs
from ..core import wire
from ..core.comm import fmt_bytes
from ..core.llm_dsfl import (LLMDsflHP, dsfl_round_step, predict_open_probs,
                             sgd_train_step)
from ..data.pipeline import lm_open_batch, lm_private_batches
from ..models.api import model_init
from ..models.base import param_count
from ..checkpoint import save_pytree


def extra_inputs(cfg, batch, key):
    out = {}
    if cfg.arch_type == "vlm":
        out["patches"] = jax.random.normal(
            key, (batch, cfg.n_patches, cfg.d_model), jnp.float32
        ).astype(cfg.cdtype)
    if cfg.arch_type == "audio":
        out["frames"] = jax.random.normal(
            key, (batch, cfg.n_audio_frames, cfg.d_model), jnp.float32
        ).astype(cfg.cdtype)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=list_archs())
    ap.add_argument("--mode", default="dsfl", choices=["dsfl", "local"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--aggregation", default="era", choices=["era", "sa"])
    ap.add_argument("--topk", type=int, default=None)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(args.seed)
    K = args.clients
    hp = LLMDsflHP(lr=args.lr, gamma=args.gamma, aggregation=args.aggregation,
                   topk=args.topk)

    print(f"arch={cfg.name} ({cfg.arch_type}) layers={cfg.n_layers} "
          f"d={cfg.d_model} vocab={cfg.vocab}")
    if args.mode == "dsfl":
        stacked = jax.vmap(lambda k: model_init(cfg, k))(
            jax.random.split(key, K))
        print(f"params/client: {param_count(jax.tree.map(lambda x: x[0], stacked)):,}")
        kd, ko, ke = jax.random.split(jax.random.fold_in(key, 1), 3)
        private = lm_private_batches(kd, K, args.batch, args.seq, cfg.vocab)
        open_b = lm_open_batch(ko, args.batch, args.seq, cfg.vocab)
        ex = extra_inputs(cfg, args.batch, ke)
        private.update({k: jnp.broadcast_to(v[None], (K,) + v.shape)
                        for k, v in ex.items()})
        open_b.update(ex)
        # measured per-round exchange bytes (eval_shape: no compute), the
        # LLM-scale analogue of the paper's Table 1/2 upload accounting
        one = jax.tree.map(lambda a: a[0], stacked)
        up = jax.eval_shape(lambda p: predict_open_probs(cfg, p, open_b), one)
        if args.topk is not None:
            up = jax.eval_shape(
                wire.TopKCodec(k=args.topk, n_classes=cfg.vocab).encode, up)
        ex_bytes = wire.nbytes(up) * (K + 1)
        fedavg_bytes = wire.nbytes(one) * (K + 1)
        print(f"exchange/round: {fmt_bytes(ex_bytes)} "
              f"(FedAvg parameter exchange would be "
              f"{fmt_bytes(fedavg_bytes)})")
        step = jax.jit(lambda p, pb, ob: dsfl_round_step(cfg, p, pb, ob, hp))
        params = stacked
        for i in range(args.steps):
            t0 = time.time()
            params, loss = step(params, private, open_b)
            loss.block_until_ready()
            print(f"round {i:3d}  loss {float(loss):.4f}  "
                  f"{time.time()-t0:.2f}s", flush=True)
    else:
        params = model_init(cfg, key)
        print(f"params: {param_count(params):,}")
        kd, ke = jax.random.split(jax.random.fold_in(key, 1))
        batch = lm_open_batch(kd, args.batch, args.seq, cfg.vocab)
        batch.update(extra_inputs(cfg, args.batch, ke))
        step = jax.jit(lambda p, b: sgd_train_step(cfg, p, b, args.lr))
        for i in range(args.steps):
            t0 = time.time()
            params, loss = step(params, batch)
            loss.block_until_ready()
            print(f"step {i:3d}  loss {float(loss):.4f}  "
                  f"{time.time()-t0:.2f}s", flush=True)

    if args.ckpt:
        save_pytree(args.ckpt, params)
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
