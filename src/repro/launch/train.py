"""Training driver.

Three modes, the federated ones running through the unified
`FedAlgorithm`/`FedEngine` API (`core.llm_algorithms`):
  * ``--mode dsfl``   - the paper's protocol at LLM scale: K simulated clients
    (vmapped; on the multi-pod mesh the client axis shards over pods), logit
    exchange on a shared open batch, ERA aggregation, hybrid CE+KD local steps.
  * ``--mode fedavg`` - Benchmark 1 at LLM scale: local SGD + parameter mean
    (the all-reduce whose bytes the paper's claim is measured against).
  * ``--mode local``  - plain LM pretraining (the "1. Update" benchmark).

The engine jits the round with mesh-aware ``in_shardings`` (client axis on
"pod" when the device count allows), donates the round state, measures the
exchange bytes on the encoded wire payload, and msgpack-checkpoints state +
round counter + history (``--ckpt``; a later run resumes the RNG stream).

``--participation``/``--straggler`` route the federated modes through the
`repro.sim` event simulator: a lognormal mobile fleet, uniform-K sampling,
and a virtual clock charged from the measured wire bytes — per-round output
then reports virtual wallclock and the participating cohort.

``--chunk-rounds k`` folds k rounds into one compiled ``lax.scan``
(`FedEngine.run(chunk_rounds=k)`) — bitwise identical to the per-round
loop, minus its per-round dispatch overhead.  Under ``--participation``/
``--straggler`` this is the *fused sim path*: the sync scheduler plans the
whole chunk's participation a priori and the (k, K) mask/stale plan rides
through the scan as per-step ctx inputs.

``--overlap`` software-pipelines the fused chunk: round r+1's logit
exchange (the cross-pod all-gather) is issued before round r's local
compute retires, so the wire hides behind compute.  Bitwise identical to
the sequential schedule — same ops, same order, split at the wire
boundary.  Pair it with ``--platform-preset overlap`` (or
``overlap-cpu8`` on CPU), which turns on XLA's latency-hiding scheduler
and async-collective lowering so the compiler actually exploits the slack
the schedule exposes.

On this CPU container use ``--smoke`` (reduced config).  Example:

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --smoke \
      --mode dsfl --clients 2 --steps 20 --chunk-rounds 5 --overlap \
      --platform-preset overlap-cpu8 [--participation 0.5 --straggler 30]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs
from ..core import wire
from ..core.comm import fmt_bytes
from ..core.engine import FedEngine
from ..core.llm_algorithms import (LLMDSFLAlgorithm, LLMFedAvgAlgorithm,
                                   LLMFedAvgHP)
from ..core.llm_dsfl import LLMDsflHP, sgd_train_step
from ..data.pipeline import build_lm_task, lm_open_batch
from ..models.api import model_init
from ..models.base import param_count
from ..models.shardctx import axis_ctx
from ..checkpoint import save_pytree
from ..obs import cli as obs_cli
from . import platform
from .mesh import make_client_mesh


def extra_inputs(cfg, batch, key):
    out = {}
    if cfg.arch_type == "vlm":
        out["patches"] = jax.random.normal(
            key, (batch, cfg.n_patches, cfg.d_model), jnp.float32
        ).astype(cfg.cdtype)
    if cfg.arch_type == "audio":
        out["frames"] = jax.random.normal(
            key, (batch, cfg.n_audio_frames, cfg.d_model), jnp.float32
        ).astype(cfg.cdtype)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=list_archs())
    ap.add_argument("--mode", default="dsfl",
                    choices=["dsfl", "fedavg", "local"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--aggregation", default="era", choices=["era", "sa"])
    ap.add_argument("--topk", type=int, default=None)
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients sampled per round (<1 runs the "
                         "round through the repro.sim event simulator)")
    ap.add_argument("--straggler", type=float, default=None,
                    help="virtual-seconds round deadline; late clients are "
                         "dropped (or admitted late with --straggler-policy)")
    ap.add_argument("--straggler-policy", default="drop",
                    choices=["drop", "admit"])
    ap.add_argument("--chunk-rounds", type=int, default=1,
                    help="rounds fused per compiled lax.scan chunk (bitwise "
                         "identical to the per-round loop); with "
                         "--participation/--straggler this runs the fused "
                         "sim path (sync participation planned per chunk)")
    ap.add_argument("--overlap", action="store_true",
                    help="software-pipeline the fused chunk: issue round "
                         "r+1's logit exchange before round r's compute "
                         "retires (bitwise identical to the sequential "
                         "schedule; needs --chunk-rounds >= 2)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    platform.add_args(ap)
    obs_cli.add_args(ap)
    args = ap.parse_args(argv)
    # apply the XLA preset BEFORE anything touches the backend (obs session
    # provenance included) — XLA_FLAGS are read once at backend init
    platform.from_args(args)
    with obs_cli.session(args):
        run(args)


def run(args):
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(args.seed)
    K = args.clients

    print(f"arch={cfg.name} ({cfg.arch_type}) layers={cfg.n_layers} "
          f"d={cfg.d_model} vocab={cfg.vocab}")
    if args.mode in ("dsfl", "fedavg"):
        task = build_lm_task(args.seed, K, args.batch, args.seq, cfg.vocab,
                             extras_fn=lambda b, k: extra_inputs(cfg, b, k))
        if args.mode == "dsfl":
            hp = LLMDsflHP(lr=args.lr, gamma=args.gamma,
                           aggregation=args.aggregation, topk=args.topk,
                           rounds=args.steps, seed=args.seed,
                           open_batch=args.batch)
            algo = LLMDSFLAlgorithm(cfg, hp)
            # the wire leg: top-k (value, index) pairs when sparsified, else
            # half-precision logits (probs travel as bf16 — 2 bytes each)
            codec = (wire.TopKCodec(k=args.topk, n_classes=cfg.vocab)
                     if args.topk else wire.FP16Codec())
        else:
            algo = LLMFedAvgAlgorithm(cfg, LLMFedAvgHP(
                lr=args.lr, rounds=args.steps, seed=args.seed))
            codec = wire.DenseF32Codec()
        mesh = make_client_mesh(K)
        eng = FedEngine(algo, codec=codec, mesh=mesh, donate_state=True)
        state = eng.init(lambda k: model_init(cfg, k), task, rng=key)
        one = jax.tree.map(lambda a: a[0], state.clients.params)
        print(f"params/client: {param_count(one):,}")
        # measured per-round exchange bytes (eval_shape: no compute), the
        # LLM-scale analogue of the paper's Table 1/2 upload accounting
        ex_bytes = eng.measured_round_bytes(state, task)
        fedavg_bytes = wire.nbytes(one) * (K + 1)
        print(f"exchange/round: {fmt_bytes(ex_bytes)} "
              f"(FedAvg parameter exchange would be "
              f"{fmt_bytes(fedavg_bytes)})")
        simulate = args.participation < 1.0 or args.straggler is not None
        if simulate and args.overlap:
            print("note: --overlap applies to the direct engine path; the "
                  "sim-routed rounds keep the sequential schedule")
        if simulate:
            # event-driven fleet: lognormal mobile links, uniform-K
            # participation, optional straggler deadline — the round runs
            # through the same engine, masked via BatchCtx.mask/stale
            from ..sim import ClientPopulation, SimRunner, SyncScheduler
            pop = ClientPopulation.lognormal(args.seed, K)
            runner = SimRunner(eng, SyncScheduler(
                pop, fraction=args.participation, deadline=args.straggler,
                straggler=args.straggler_policy), seed=args.seed)
        with axis_ctx(mesh, batch_axes=("data",)):
            done = 0
            while done < args.steps:
                k = max(1, min(args.chunk_rounds, args.steps - done))
                t0 = time.time()
                if simulate:
                    state = runner.run(state, task, rounds=k,
                                       chunk_rounds=k)
                    dt = (time.time() - t0) / k
                    for rec in runner.history.records[-k:]:
                        print(f"round {rec['round']-1:3d}  "
                              f"loss {rec['loss']:.4f}  "
                              f"vt {rec['t_cum']:8.1f}s  "
                              f"{rec['participants']}/{K} clients  "
                              f"{dt:.2f}s/round", flush=True)
                else:
                    state = eng.run(state, task, rounds=k, chunk_rounds=k,
                                    overlap=args.overlap)
                    dt = (time.time() - t0) / k
                    for rec in eng.history[-k:]:
                        print(f"round {rec['round']-1:3d}  "
                              f"loss {rec['loss']:.4f}  "
                              f"{dt:.2f}s/round", flush=True)
                done += k
        if args.ckpt:
            if simulate:
                runner.save_state(args.ckpt, state)   # + .sim.json sidecar
            else:
                eng.save_state(args.ckpt, state)
            print("saved", args.ckpt)
    else:
        params = model_init(cfg, key)
        print(f"params: {param_count(params):,}")
        kd, ke = jax.random.split(jax.random.fold_in(key, 1))
        batch = lm_open_batch(kd, args.batch, args.seq, cfg.vocab)
        batch.update(extra_inputs(cfg, args.batch, ke))
        step = jax.jit(lambda p, b: sgd_train_step(cfg, p, b, args.lr))
        for i in range(args.steps):
            t0 = time.time()
            params, loss = step(params, batch)
            loss.block_until_ready()
            print(f"step {i:3d}  loss {float(loss):.4f}  "
                  f"{time.time()-t0:.2f}s", flush=True)
        if args.ckpt:
            save_pytree(args.ckpt, params)
            print("saved", args.ckpt)


if __name__ == "__main__":
    main()
