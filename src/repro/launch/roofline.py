"""Roofline-term extraction from compiled dry-run artifacts.

  compute    = per-device HLO FLOPs / peak FLOP/s          (cost_analysis)
  memory     = per-device HLO bytes accessed / HBM BW      (cost_analysis)
  collective = per-device collective bytes / ICI link BW   (parsed from HLO)

``cost_analysis()`` on a compiled SPMD executable reports PER-DEVICE numbers
(verified in this container: a (4096x4096x4096) matmul sharded 512 ways
reports total/512 flops), so no further chip division is applied.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (one link per mesh dim direction; we charge the sum of collective operand
bytes against a single link, a conservative upper bound).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[^\]]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective kind (per-device view)."""
    out: dict[str, int] = {}
    for type_str, kind in _COLL_RE.findall(hlo_text):
        out[kind] = out.get(kind, 0) + _shape_bytes(type_str)
    return out


_LINE_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[^\]]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\((.*)$", re.M)
_GROUPS_RE = re.compile(r"replica_groups=(\[[\d,]+\]<=\[[\d,]+\](?:T\([\d,]+\))?|\{\{[^}]*\}[^}]*\})")


def _spans_pods(groups_str: str, pod_size: int = 256) -> bool:
    """True if any replica group contains devices from different pods
    (device id // pod_size differs).  Handles both explicit {{0,256},...}
    and iota [g,n]<=[...] forms."""
    if groups_str.startswith("{{"):
        for grp in groups_str.strip("{}").split("},{"):
            ids = [int(x) for x in grp.replace("{", "").replace("}", "")
                   .split(",") if x.strip().isdigit()]
            if len({i // pod_size for i in ids}) > 1:
                return True
        return False
    # iota form [groups,per_group]<=[dims...](T(perm)): reconstruct
    m = re.match(r"\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
                 groups_str)
    if not m:
        return True          # conservative
    import numpy as np
    g, n = int(m.group(1)), int(m.group(2))
    dims = [int(x) for x in m.group(3).split(",")]
    ids = np.arange(int(np.prod(dims))).reshape(dims)
    if m.group(4):
        perm = [int(x) for x in m.group(4).split(",")]
        ids = ids.transpose(perm)
    ids = ids.reshape(g, n)
    return bool(np.any((ids // pod_size).min(1) != (ids // pod_size).max(1)))


def cross_pod_bytes(hlo_text: str, pod_size: int = 256) -> dict[str, int]:
    """Collective bytes restricted to ops whose replica groups SPAN pods —
    the inter-pod (data-center-interconnect) traffic of the step."""
    out: dict[str, int] = {}
    for mt in _LINE_RE.finditer(hlo_text):
        type_str, kind, rest = mt.groups()
        gm = _GROUPS_RE.search(rest)
        spans = _spans_pods(gm.group(1), pod_size) if gm else False
        if spans:
            out[kind] = out.get(kind, 0) + _shape_bytes(type_str)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    step: str
    flops: float                 # per device
    bytes_accessed: float        # per device
    coll_bytes: float            # per device
    coll_breakdown: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float           # 6*N_active*D global "useful" flops
    useful_ratio: float          # model_flops / (flops * n_devices)
    peak_mem_bytes: float        # per-device temp+output allocation
    arg_bytes: float

    @classmethod
    def from_terms(cls, *, arch, shape, mesh_name, step, flops,
                   bytes_accessed, coll, n_devices, model_flops, mem):
        cb = float(sum(coll.values()))
        tc = flops / PEAK_FLOPS
        tm = bytes_accessed / HBM_BW
        tx = cb / ICI_BW
        terms = {"compute": tc, "memory": tm, "collective": tx}
        total_hlo = flops * n_devices
        return cls(
            arch=arch, shape=shape, mesh=mesh_name, step=step, flops=flops,
            bytes_accessed=bytes_accessed, coll_bytes=cb, coll_breakdown=coll,
            t_compute=tc, t_memory=tm, t_collective=tx,
            bottleneck=max(terms, key=terms.get),
            model_flops=model_flops,
            useful_ratio=(model_flops / total_hlo) if total_hlo else 0.0,
            peak_mem_bytes=float(mem.temp_size_in_bytes
                                 + mem.output_size_in_bytes),
            arg_bytes=float(mem.argument_size_in_bytes),
        )

    @classmethod
    def build(cls, *, arch, shape, mesh_name, step, compiled, n_devices,
              model_flops):
        ca = compiled.cost_analysis()
        return cls.from_terms(
            arch=arch, shape=shape, mesh_name=mesh_name, step=step,
            flops=float(ca.get("flops", 0.0)),
            bytes_accessed=float(ca.get("bytes accessed", 0.0)),
            coll=collective_bytes(compiled.as_text()), n_devices=n_devices,
            model_flops=model_flops, mem=compiled.memory_analysis())

    def to_dict(self):
        return asdict(self)


def model_flops_estimate(cfg, shape) -> float:
    """6 * N_active * tokens (training) or 2 * N_active * tokens (fwd-only).
    N_active counts each token's parameter traffic (MoE: top_k experts)."""
    d, L = cfg.d_model, cfg.n_layers
    n_attn = sum(1 for m, _ in cfg.pattern if m == "attn") * cfg.n_blocks
    n_mamba = sum(1 for m, _ in cfg.pattern if m == "mamba") * cfg.n_blocks
    n_mlp = sum(1 for _, f in cfg.pattern if f == "mlp") * cfg.n_blocks
    n_moe = sum(1 for _, f in cfg.pattern if f == "moe") * cfg.n_blocks
    hd = cfg.hd if cfg.n_heads else 0
    attn_p = (cfg.n_heads * hd * d * 2 + cfg.n_kv_heads * hd * d * 2) if n_attn else 0
    mlp_mult = 3 if cfg.act in ("swiglu", "geglu") else 2
    mlp_p = mlp_mult * d * cfg.d_ff
    moe_p = mlp_mult * d * cfg.d_ff * max(cfg.top_k, 1)
    di = cfg.d_inner if n_mamba else 0
    gn = cfg.ssm_groups * cfg.ssm_state if n_mamba else 0
    mamba_p = di * d * 3 + gn * d * 2 + cfg.ssm_heads * d if n_mamba else 0
    embed_p = d * cfg.vocab                       # unembed matmul
    n_active = (n_attn * attn_p + n_mlp * mlp_p + n_moe * moe_p
                + n_mamba * mamba_p + embed_p)
    if cfg.arch_type == "audio":
        n_active += cfg.enc_layers * (4 * d * d + mlp_mult * d * cfg.d_ff) \
            + cfg.n_layers * 4 * d * d            # enc + cross-attn
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult * n_active * tokens)
