"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation.  The dry-run lowers against these."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.shapes import LONG_CONTEXT_WINDOW, InputShape
from ..models.api import model_init, model_init_cache
from ..models.base import ModelConfig

I32 = jnp.int32
BF16 = jnp.bfloat16
F32 = jnp.float32


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def effective_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """long_500k on full-attention archs runs the sliding-window variant
    (DESIGN.md §4); SSM/hybrid run natively."""
    if shape.name == "long_500k" and cfg.arch_type not in ("ssm", "hybrid"):
        return cfg.replace(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(lambda: model_init(cfg, jax.random.PRNGKey(0)))


def batch_struct(cfg: ModelConfig, batch: int, seq: int) -> dict:
    b = {"tokens": sds((batch, seq), I32)}
    if cfg.arch_type == "vlm":
        b["patches"] = sds((batch, cfg.n_patches, cfg.d_model), BF16)
    if cfg.arch_type == "audio":
        b["frames"] = sds((batch, cfg.n_audio_frames, cfg.d_model), BF16)
    return b


def teacher_struct(cfg: ModelConfig, batch: int, seq: int,
                   topk: int | None = None):
    if topk is not None:
        return (sds((batch, seq, topk), F32), sds((batch, seq, topk), I32))
    return sds((batch, seq, cfg.eff_vocab), BF16)


def cache_struct(cfg: ModelConfig, batch: int, seq_len: int):
    if cfg.arch_type == "audio":
        frames = sds((batch, cfg.n_audio_frames, cfg.d_model), BF16)
        params = params_struct(cfg)
        return jax.eval_shape(
            lambda p, f: model_init_cache(cfg, p, batch, seq_len,
                                          {"frames": f}), params, frames)
    return jax.eval_shape(lambda: model_init_cache(cfg, None, batch, seq_len))


def input_specs(cfg: ModelConfig, shape: InputShape, *,
                n_clients: int = 1, topk: int | None = None) -> dict:
    """All jit inputs for the step this (arch x shape) lowers.

    train  -> {params, private, open, teacher}  (DS-FL hybrid client step;
               with n_clients > 1 the leaves gain a leading client axis for
               the pod-sharded round step)
    prefill-> {params, open}                    (DS-FL prediction pass)
    decode -> {params, cache, token, pos}       (serve_step)
    """
    cfg = effective_config(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    params = params_struct(cfg)
    out = {"cfg": cfg}
    if shape.kind == "train":
        if n_clients > 1:
            Bc = B // n_clients
            stack = lambda t: jax.tree.map(
                lambda l: sds((n_clients,) + l.shape, l.dtype), t)
            out["params"] = stack(params)
            out["private"] = stack(batch_struct(cfg, Bc, S))
            out["open"] = batch_struct(cfg, Bc, S)
        else:
            out["params"] = params
            out["private"] = batch_struct(cfg, B, S)
            out["open"] = batch_struct(cfg, B, S)
            out["teacher"] = teacher_struct(cfg, B, S, topk)
    elif shape.kind == "prefill":
        out["params"] = params
        out["open"] = batch_struct(cfg, B, S)
    else:  # decode
        out["params"] = params
        out["cache"] = cache_struct(cfg, B, S)
        out["token"] = sds((B,), I32)
        out["pos"] = sds((), I32)
    return out
