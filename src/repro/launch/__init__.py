from . import mesh, roofline, sharding, specs  # noqa  (dryrun sets XLA_FLAGS; import explicitly)
