"""Whisper-style encoder-decoder transformer backbone.

Per the assignment carve-out the mel-spectrogram + conv feature extractor is a
STUB: ``input_specs`` feeds precomputed frame embeddings (B, F, D).  Everything
downstream — encoder self-attention stack, decoder with causal self-attention,
cross-attention, learned decoder positions, KV-cached decode — is real.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import (attn_decode_step, attn_forward, cross_attn_forward,
                        cross_kv, init_attn, init_kv_cache)
from .base import ModelConfig
from .layers import _init, embed, init_embed, init_mlp, init_rmsnorm, mlp, \
    rmsnorm, unembed
from .shardctx import constrain


def _sinusoid(F: int, D: int) -> jax.Array:
    pos = jnp.arange(F, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, D, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (dim / D))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_encdec(cfg: ModelConfig, key) -> dict:
    ke, kp, kenc, kdec = jax.random.split(key, 4)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {"n1": init_rmsnorm(cfg.d_model), "attn": init_attn(k1, cfg),
                "n2": init_rmsnorm(cfg.d_model), "mlp": init_mlp(k2, cfg)}

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"n1": init_rmsnorm(cfg.d_model), "self": init_attn(k1, cfg),
                "n2": init_rmsnorm(cfg.d_model), "cross": init_attn(k2, cfg),
                "n3": init_rmsnorm(cfg.d_model), "mlp": init_mlp(k3, cfg)}

    return {
        "embed": init_embed(ke, cfg),
        "pos_dec": _init(kp, (cfg.max_seq, cfg.d_model), 0.01, cfg.cdtype),
        "enc": jax.vmap(enc_block)(jax.random.split(kenc, cfg.enc_layers)),
        "dec": jax.vmap(dec_block)(jax.random.split(kdec, cfg.n_layers)),
        "enc_norm": init_rmsnorm(cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
    }


def encode(cfg: ModelConfig, params: dict, frames: jax.Array,
           remat: bool = True) -> jax.Array:
    """frames: (B, F, D) conv-stub output.  Returns encoder states."""
    x = frames.astype(cfg.cdtype) + _sinusoid(frames.shape[1], cfg.d_model
                                              ).astype(cfg.cdtype)

    def blk(bp, h):
        h = h + attn_forward(bp["attn"], cfg, rmsnorm(bp["n1"], h, cfg.norm_eps),
                             causal=False)
        h = h + mlp(bp["mlp"], cfg, rmsnorm(bp["n2"], h, cfg.norm_eps))
        return h

    f = jax.checkpoint(blk) if remat else blk

    def body(h, bp):
        return constrain(f(bp, h), "batch", None, None), None

    x, _ = jax.lax.scan(body, x, params["enc"],
                        unroll=cfg.enc_layers if cfg.scan_unroll else 1)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def decoder_logits(cfg: ModelConfig, params: dict, tokens: jax.Array,
                   enc_out: jax.Array, remat: bool = True) -> jax.Array:
    """Teacher-forced decoder. tokens: (B, S) -> logits (B, S, V)."""
    B, S = tokens.shape
    x = embed(params["embed"], cfg, tokens) + params["pos_dec"][:S]

    def blk(bp, h):
        h = h + attn_forward(bp["self"], cfg, rmsnorm(bp["n1"], h, cfg.norm_eps))
        ek, ev = cross_kv(bp["cross"], cfg, enc_out)
        h = h + cross_attn_forward(bp["cross"], cfg,
                                   rmsnorm(bp["n2"], h, cfg.norm_eps), ek, ev)
        h = h + mlp(bp["mlp"], cfg, rmsnorm(bp["n3"], h, cfg.norm_eps))
        return h

    f = jax.checkpoint(blk) if remat else blk

    def body(h, bp):
        return constrain(f(bp, h), "batch", None, None), None

    x, _ = jax.lax.scan(body, x, params["dec"],
                        unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return constrain(unembed(params["embed"], cfg, x), "batch", None, None)


def encdec_lm_logits(cfg: ModelConfig, params: dict, tokens: jax.Array,
                     frames: jax.Array, remat: bool = True):
    enc_out = encode(cfg, params, frames, remat)
    logits = decoder_logits(cfg, params, tokens, enc_out, remat)
    return logits, jnp.zeros((), jnp.float32)


# ------------------------------------------------------------------ decode ---
def init_encdec_cache(cfg: ModelConfig, params: dict, batch: int,
                      seq_len: int, enc_out: jax.Array) -> dict:
    """Self-attn ring buffers + precomputed cross K/V per decoder layer."""
    W = min(seq_len, cfg.sliding_window or seq_len)
    kv = init_kv_cache(cfg, batch, W)
    self_cache = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), kv)

    def per_layer(bp):
        return cross_kv(bp["cross"], cfg, enc_out)

    ck, cv = jax.vmap(per_layer)(params["dec"])       # (L, B, Se, Kh, hd)
    return {"self": self_cache, "cross_k": ck, "cross_v": cv}


def encdec_decode_step(cfg: ModelConfig, params: dict, cache: dict,
                       token: jax.Array, pos: jax.Array):
    """One decoder token. Returns (logits (B, V), new cache)."""
    x = embed(params["embed"], cfg, token[:, None]) \
        + jnp.take(params["pos_dec"], pos[None], axis=0)

    def body(h, xs):
        bp, sc, ck, cv = xs
        hh = rmsnorm(bp["n1"], h, cfg.norm_eps)
        out, nsc = attn_decode_step(bp["self"], cfg, hh, sc, pos)
        h = h + out
        hh = rmsnorm(bp["n2"], h, cfg.norm_eps)
        q = hh @ bp["cross"]["wq"]
        if "bq" in bp["cross"]:
            q = q + bp["cross"]["bq"]
        B = h.shape[0]
        q = q.reshape(B, cfg.eff_heads, cfg.hd)
        s = jnp.einsum("bhd,bshd->bhs", q, ck).astype(jnp.float32) \
            * cfg.hd ** -0.5
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhs,bshd->bhd", w.astype(cv.dtype), cv)
        from .attention import head_mask
        o = head_mask(cfg, o[:, None])[:, 0]
        h = h + (o.reshape(B, 1, cfg.eff_heads * cfg.hd) @ bp["cross"]["wo"])
        h = h + mlp(bp["mlp"], cfg, rmsnorm(bp["n3"], h, cfg.norm_eps))
        return h, nsc

    x, new_self = jax.lax.scan(
        body, x, (params["dec"], cache["self"], cache["cross_k"],
                  cache["cross_v"]),
        unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], cfg, x)[:, 0]
    return logits, {"self": new_self, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}
