"""Mixture-of-Experts FFN with GShard-style grouped capacity dispatch.

Tokens are viewed as (groups, group_size); each group dispatches at most
``capacity`` tokens to each expert through one-hot einsums (no scatter), which
is the TPU-idiomatic formulation: the dispatch/combine einsums lower to
all-to-alls when the expert dim is sharded over the model axis.

FLOPs are *active-expert* FLOPs (E x C x D x F with E*C ~= tokens*top_k*cf),
so roofline compute terms reflect the MoE advantage.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .base import ModelConfig
from .layers import _init
from .shardctx import constrain


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "router": _init(ks[0], (d, e), s_in, jnp.float32),
        "w_gate": _init(ks[1], (e, d, f), s_in, cfg.cdtype),
        "w_up": _init(ks[2], (e, d, f), s_in, cfg.cdtype),
        "w_down": _init(ks[3], (e, f, d), s_out, cfg.cdtype),
    }


def capacity(cfg: ModelConfig, group_size: int) -> int:
    c = math.ceil(group_size * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(c, 1)


def moe_ffn(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).  Token-choice top-k with per-group
    capacity; overflow tokens are dropped (pass through the residual)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    gs = min(cfg.moe_group_size, B * S)
    N = B * S
    assert N % gs == 0, (N, gs)
    G = N // gs
    C = capacity(cfg, gs)

    xg = x.reshape(G, gs, D)
    logits = xg.astype(jnp.float32) @ p["router"]            # (G, gs, E)
    gates = jax.nn.softmax(logits, axis=-1)

    # load-balance auxiliary loss (Switch/GShard style)
    me = jnp.mean(gates, axis=1)                              # (G, E)
    top1 = jnp.argmax(gates, axis=-1)
    ce = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=1)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * E

    topk_g, topk_i = jax.lax.top_k(gates, K)                  # (G, gs, K)
    topk_g = topk_g / jnp.maximum(jnp.sum(topk_g, -1, keepdims=True), 1e-9)

    # position of each (token, choice) inside its expert's capacity buffer
    oh = jax.nn.one_hot(topk_i, E, dtype=jnp.int32)           # (G, gs, K, E)
    ohf = oh.reshape(G, gs * K, E)
    pos = jnp.cumsum(ohf, axis=1) - ohf                       # (G, gs*K, E)
    pos = jnp.sum(pos * ohf, axis=-1).reshape(G, gs, K)       # rank in expert
    keep = pos < C

    # dispatch / combine tensors
    disp = (jax.nn.one_hot(topk_i, E, dtype=x.dtype)[..., :, None]
            * jax.nn.one_hot(pos, C, dtype=x.dtype)[..., None, :]
            * keep[..., None, None].astype(x.dtype))          # (G, gs, K, E, C)
    comb = disp * topk_g[..., None, None].astype(x.dtype)
    disp = jnp.sum(disp, axis=2)                              # (G, gs, E, C)
    comb = jnp.sum(comb, axis=2)

    xin = constrain(jnp.einsum("gsec,gsd->egcd", disp, xg),
                    "model", "batch", None, None)             # (E, G, C, D)
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xin, p["w_gate"])) \
            * jnp.einsum("egcd,edf->egcf", xin, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("egcd,edf->egcf", xin, p["w_up"]),
                        approximate=True)
    eout = constrain(jnp.einsum("egcf,efd->egcd", h, p["w_down"]),
                     "model", "batch", None, None)            # (E, G, C, D)
    out = constrain(jnp.einsum("gsec,egcd->gsd", comb, eout),
                    "batch", None, None)
    return out.reshape(B, S, D), aux
