"""Primitive layers: norms, MLPs, RoPE, embeddings.

All functions are pure; parameters are plain dict pytrees.  Matmul inputs are
kept in ``cfg.dtype`` (bf16 on TPU) with fp32 normalization statistics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ModelConfig


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ----------------------------------------------------------------- norms ----
def init_rmsnorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# ------------------------------------------------------------------ MLPs ----
def init_mlp(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = f ** -0.5
    p = {"w_down": _init(k3, (f, d), s_out, cfg.cdtype)}
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = _init(k1, (d, f), s_in, cfg.cdtype)
        p["w_up"] = _init(k2, (d, f), s_in, cfg.cdtype)
    else:  # gelu / relu
        p["w_up"] = _init(k2, (d, f), s_in, cfg.cdtype)
        p["b_up"] = jnp.zeros((f,), cfg.cdtype)
        p["b_down"] = jnp.zeros((d,), cfg.cdtype)
    return p


def mlp(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"] + p["b_up"], approximate=True)
    out = h @ p["w_down"]
    if "b_down" in p:
        out = out + p["b_down"]
    return out


# ------------------------------------------------------------------ RoPE ----
def rope_freqs(cfg: ModelConfig, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """positions: (...,) int32 -> cos/sin of shape (..., hd/2) in fp32."""
    hd = cfg.hd
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * inv      # (..., hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (S, hd/2) or (B, S, hd/2)."""
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    if cos.ndim == 2:                       # (S, hd/2) -> broadcast over B, H
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:                                   # (B, S, hd/2)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ embeddings ----
def init_embed(key, cfg: ModelConfig) -> dict:
    V = cfg.eff_vocab
    p = {"tok": _init(key, (V, cfg.d_model), 1.0, cfg.cdtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = _init(jax.random.fold_in(key, 1),
                             (cfg.d_model, V), cfg.d_model ** -0.5, cfg.cdtype)
    return p


def embed(p: dict, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0).astype(cfg.cdtype)


def unembed(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    w = p["unembed"] if "unembed" in p else p["tok"].T
    logits = x @ w
    if cfg.eff_vocab != cfg.vocab:      # mask padded vocab columns to -inf
        neg = jnp.asarray(-1e30, logits.dtype)
        mask = jnp.arange(cfg.eff_vocab) < cfg.vocab
        logits = jnp.where(mask, logits, neg)
    return logits
