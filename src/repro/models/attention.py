"""Grouped-query attention with RoPE, optional QKV bias, sliding windows,
flash-style chunked softmax (memory-safe at 32k prefill) and a ring-buffer
KV cache for decode.

Shapes: q (B, Sq, H, hd) / k, v (B, Skv, Kh, hd); GQA groups G = H // Kh.
All softmax statistics accumulate in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ModelConfig
from .shardctx import constrain
from .layers import _init, apply_rope, rope_freqs

NEG_INF = -1e30


# ------------------------------------------------------------------ init ----
def init_attn(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kh, hd = cfg.d_model, cfg.eff_heads, cfg.eff_kv_heads, cfg.hd
    if cfg.pad_heads:
        assert cfg.n_kv_heads == cfg.n_heads, "pad_heads requires MHA" 
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": _init(ks[0], (d, h * hd), s, cfg.cdtype),
        "wk": _init(ks[1], (d, kh * hd), s, cfg.cdtype),
        "wv": _init(ks[2], (d, kh * hd), s, cfg.cdtype),
        "wo": _init(ks[3], (h * hd, d), (h * hd) ** -0.5, cfg.cdtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), cfg.cdtype)
        p["bk"] = jnp.zeros((kh * hd,), cfg.cdtype)
        p["bv"] = jnp.zeros((kh * hd,), cfg.cdtype)
    return p


def qkv_proj(p: dict, cfg: ModelConfig, x: jax.Array):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.eff_heads, cfg.hd)
    k = k.reshape(B, S, cfg.eff_kv_heads, cfg.hd)
    v = v.reshape(B, S, cfg.eff_kv_heads, cfg.hd)
    return q, k, v


def head_mask(cfg: ModelConfig, o: jax.Array) -> jax.Array:
    """Zero the padded heads so pad_heads preserves numerics exactly
    (padded wo rows then contribute nothing and receive no gradient)."""
    if not cfg.pad_heads or cfg.pad_heads == cfg.n_heads:
        return o
    mask = (jnp.arange(cfg.eff_heads) < cfg.n_heads).astype(o.dtype)
    return o * mask[..., :, None]


def _fit_chunk(S: int, c: int) -> int:
    """Largest divisor of S that is <= c (static, trace-time)."""
    c = min(c, S)
    while S % c:
        c -= 1
    return c


# -------------------------------------------------- flash-style attention ----
def _chunk_attn(q, k, v, q_pos, kv_pos, scale, causal, window):
    """One (q-chunk, kv-chunk) tile.  q: (B,Kh,G,Cq,hd) k/v: (B,Ckv,Kh,hd).
    Returns unnormalized (m, l, acc) contributions in fp32."""
    s = jnp.einsum("bkgqd,bckd->bkgqc", q, k).astype(jnp.float32) * scale
    mask = jnp.ones(s.shape[-2:], bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window is not None:
        mask &= kv_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # (B,Kh,G,Cq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(v.dtype), v).astype(jnp.float32)
    return m, l, acc


def flash_attention(q, k, v, *, causal=True, window=None,
                    q_chunk=1024, kv_chunk=1024, q_offset=0):
    """Chunked online-softmax attention.  q: (B,Sq,H,hd), k/v: (B,Skv,Kh,hd).
    q chunks are unrolled in Python (static triangular structure keeps causal
    FLOPs ~halved); kv chunks run under ``lax.scan``."""
    B, Sq, H, hd = q.shape
    _, Skv, Kh, _ = k.shape
    G = H // Kh
    scale = hd ** -0.5
    q_chunk = _fit_chunk(Sq, q_chunk)
    kv_chunk = _fit_chunk(Skv, kv_chunk)
    nq, nkv = Sq // q_chunk, Skv // kv_chunk

    qg = q.reshape(B, Sq, Kh, G, hd)
    outs = []
    for i in range(nq):
        qi = qg[:, i * q_chunk:(i + 1) * q_chunk].transpose(0, 2, 3, 1, 4)  # B,Kh,G,Cq,hd
        q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk)
        # static causal/window range of kv chunks for this q chunk
        hi = nkv
        lo = 0
        if causal:
            hi = min(nkv, (q_offset + (i + 1) * q_chunk + kv_chunk - 1) // kv_chunk)
        if window is not None:
            lo = max(0, (q_offset + i * q_chunk - window + 1) // kv_chunk)
        n_ch = max(hi - lo, 1)
        ks = k[:, lo * kv_chunk:(lo + n_ch) * kv_chunk].reshape(B, n_ch, kv_chunk, Kh, hd)
        vs = v[:, lo * kv_chunk:(lo + n_ch) * kv_chunk].reshape(B, n_ch, kv_chunk, Kh, hd)

        def body(carry, xs):
            m, l, acc = carry
            (kc, vc, ci) = xs
            kv_pos = (lo + ci) * kv_chunk + jnp.arange(kv_chunk)
            mc, lc, accc = _chunk_attn(qi, kc, vc, q_pos, kv_pos, scale, causal, window)
            m_new = jnp.maximum(m, mc)
            a1 = jnp.exp(m - m_new)
            a2 = jnp.exp(mc - m_new)
            return (m_new, l * a1 + lc * a2,
                    acc * a1[..., None] + accc * a2[..., None]), None

        m0 = jnp.full((B, Kh, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kh, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Kh, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (ks.transpose(1, 0, 2, 3, 4), vs.transpose(1, 0, 2, 3, 4),
             jnp.arange(n_ch)))
        o = acc / jnp.maximum(l, 1e-30)[..., None]            # (B,Kh,G,Cq,hd)
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, H, hd))
    return jnp.concatenate(outs, axis=1).astype(q.dtype) if nq > 1 else outs[0].astype(q.dtype)


# ------------------------------------------------------------- self-attn ----
def attn_forward(p: dict, cfg: ModelConfig, x: jax.Array, *, positions=None,
                 causal=True, q_chunk=1024, kv_chunk=1024) -> jax.Array:
    """Training / prefill self-attention over the full sequence."""
    B, S, _ = x.shape
    q, k, v = qkv_proj(p, cfg, x)
    if cfg.pos_embed == "rope":
        if positions is None:
            positions = jnp.arange(S)
        cos, sin = rope_freqs(cfg, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)
    o = head_mask(cfg, flash_attention(q, k, v, causal=causal,
                                       window=cfg.sliding_window,
                                       q_chunk=q_chunk, kv_chunk=kv_chunk))
    return o.reshape(B, S, cfg.eff_heads * cfg.hd) @ p["wo"]


# ----------------------------------------------------------- decode cache ----
def init_kv_cache(cfg: ModelConfig, batch: int, window: int) -> dict:
    kh, hd = cfg.eff_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, window, kh, hd), cfg.cdtype),
        "v": jnp.zeros((batch, window, kh, hd), cfg.cdtype),
    }


def attn_decode_step(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict,
                     pos: jax.Array) -> tuple[jax.Array, dict]:
    """One-token decode.  x: (B, 1, D); cache k/v: (B, W, Kh, hd) ring buffer
    holding (RoPE'd) keys for positions (pos-W, pos-1] written at slot t % W.
    ``pos`` is the current token's position (scalar int32)."""
    B, _, _ = x.shape
    W = cache["k"].shape[1]
    q, k, v = qkv_proj(p, cfg, x)
    if cfg.pos_embed == "rope":
        cos, sin = rope_freqs(cfg, pos[None])
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    slot = pos % W
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))

    # position held by each slot j: largest t <= pos with t ≡ j (mod W)
    j = jnp.arange(W)
    slot_pos = pos - ((pos - j) % W)
    valid = (slot_pos >= 0) & (slot_pos > pos - W)
    if cfg.sliding_window is not None:
        valid &= slot_pos > pos - cfg.sliding_window

    Kh, G, hd = cfg.eff_kv_heads, cfg.eff_heads // cfg.eff_kv_heads, cfg.hd
    qg = q.reshape(B, Kh, G, hd)
    s = jnp.einsum("bkgd,bckd->bkgc", qg, ck).astype(jnp.float32) * hd ** -0.5
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckd->bkgd", w.astype(cv.dtype), cv)
    o = head_mask(cfg, o.reshape(B, 1, Kh * G, hd)).reshape(B, 1, Kh * G * hd) @ p["wo"]
    return o, {"k": ck, "v": cv}


# ----------------------------------------------------------- cross-attn -----
def cross_attn_forward(p: dict, cfg: ModelConfig, x: jax.Array,
                       enc_k: jax.Array, enc_v: jax.Array) -> jax.Array:
    """Decoder cross-attention (whisper).  enc_k/v precomputed: (B, Se, Kh, hd).
    No RoPE on cross-attention."""
    B, S, _ = x.shape
    q = (x @ p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, cfg.eff_heads, cfg.hd)
    o = head_mask(cfg, flash_attention(q, enc_k, enc_v, causal=False))
    return o.reshape(B, S, cfg.eff_heads * cfg.hd) @ p["wo"]


def cross_kv(p: dict, cfg: ModelConfig, enc_out: jax.Array):
    B, Se, _ = enc_out.shape
    k = enc_out @ p["wk"]
    v = enc_out @ p["wv"]
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return (k.reshape(B, Se, cfg.eff_kv_heads, cfg.hd),
            v.reshape(B, Se, cfg.eff_kv_heads, cfg.hd))
