"""Model configuration language shared by every architecture in the zoo.

One frozen dataclass describes all six architecture families (dense, moe, ssm,
hybrid, vlm, audio).  A model is a repeated ``block_pattern``: each entry is a
``(mixer, ffn)`` pair with ``mixer in {"attn", "mamba"}`` and
``ffn in {"mlp", "moe", "none"}``.  Dense archs use ``[("attn", "mlp")]``,
Mamba2 uses ``[("mamba", "none")]``, Jamba interleaves, etc.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax.numpy as jnp

Pattern = tuple[tuple[str, str], ...]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str              # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int               # total sub-layers (= n_blocks * len(pattern))
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int | None = None          # default: d_model // n_heads
    # §Perf optimizations (beyond-paper; numerics-preserving):
    pad_heads: int = 0       # pad MHA head count to TP-divisible; extra heads
                             # masked to zero (requires n_heads == n_kv_heads)
    pad_vocab: int = 0       # pad embedding/logit vocab dim to TP-divisible;
                             # padded logits masked to -inf
    qkv_bias: bool = False
    act: str = "swiglu"                  # swiglu | geglu | gelu
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    pos_embed: str = "rope"              # rope | learned (whisper)
    max_seq: int = 32_768
    sliding_window: int | None = None    # attention window; None = full causal
    tie_embeddings: bool = True

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 256            # GShard dispatch group size (tokens)

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_groups: int = 1

    # --- hybrid pattern ---
    block_pattern: Pattern = ()          # empty => derived from arch_type

    # --- modality frontends (stubbed per assignment carve-out) ---
    n_patches: int = 0                   # vlm: patch embeddings per image
    n_audio_frames: int = 0              # audio: encoder frames after conv stub
    enc_layers: int = 0                  # audio: encoder depth

    dtype: str = "bfloat16"
    # dry-run: unroll the layer scan so cost_analysis counts every layer
    # (XLA reports while-loop bodies once) — see launch/roofline.py
    scan_unroll: bool = False
    # citation for the config (paper/model card)
    source: str = ""

    # ----- derived -----
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def eff_heads(self) -> int:          # padded head count (see pad_heads)
        return self.pad_heads or self.n_heads

    @property
    def eff_kv_heads(self) -> int:
        if self.pad_heads and self.n_kv_heads == self.n_heads:
            return self.pad_heads
        return self.n_kv_heads

    @property
    def eff_vocab(self) -> int:
        return self.pad_vocab or self.vocab

    @property
    def pattern(self) -> Pattern:
        if self.block_pattern:
            return self.block_pattern
        if self.arch_type == "ssm":
            return (("mamba", "none"),)
        return (("attn", "moe" if self.n_experts else "mlp"),)

    @property
    def n_blocks(self) -> int:
        p = self.pattern
        assert self.n_layers % len(p) == 0, (self.name, self.n_layers, len(p))
        return self.n_layers // len(p)

    @property
    def d_inner(self) -> int:            # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def cdtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Reduced variant for CPU smoke tests: same family / pattern semantics,
    # 2 pattern-repeats, tiny dims, <=4 experts.
    def smoke(self) -> "ModelConfig":
        p = self.pattern
        kv = min(self.n_kv_heads, 4)
        if kv:
            nh = max(kv, min(self.n_heads, 4))
            nh = (nh // kv) * kv or kv
        else:
            nh = 0
        return self.replace(
            n_layers=2 * len(p),
            d_model=128,
            n_heads=nh,
            n_kv_heads=kv,
            head_dim=32 if self.head_dim else None,
            d_ff=256,
            vocab=max(self.vocab and 512, 512),
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 32),
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=16,
            max_seq=256,
            sliding_window=None,
            n_patches=min(self.n_patches, 16),
            n_audio_frames=min(self.n_audio_frames, 32),
            enc_layers=min(self.enc_layers, 2),
            moe_group_size=16,
            dtype="float32",
        )


def param_count(params) -> int:
    import jax
    return sum(int(x.size) for x in jax.tree.leaves(params))
