"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Chunked SSD forward: within-chunk quadratic (attention-like, MXU-friendly)
blocks + inter-chunk linear recurrence over chunk states via ``lax.scan``.
Decode is the O(1) recurrent step carrying (ssm_state, conv_state).

The x/B/C projections and their causal convs are SEPARATE parameter leaves
(w_x / w_b / w_c) so each output dim shards cleanly over the model axis —
a fused xBC projection would put TP shard boundaries inside segment
boundaries and force re-sharding collectives at the split.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ModelConfig
from .layers import _init
from .shardctx import constrain

F32 = jnp.float32


def init_mamba(key, cfg: ModelConfig) -> dict:
    d, di, h = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    gn = cfg.ssm_groups * cfg.ssm_state
    w = cfg.ssm_conv
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    dt = jnp.exp(jax.random.uniform(ks[6], (h,), F32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    return {
        "w_z": _init(ks[0], (d, di), s, cfg.cdtype),
        "w_x": _init(ks[1], (d, di), s, cfg.cdtype),
        "w_b": _init(ks[2], (d, gn), s, cfg.cdtype),
        "w_c": _init(ks[3], (d, gn), s, cfg.cdtype),
        "cw_x": _init(ks[4], (w, di), di ** -0.5, cfg.cdtype),
        "cw_b": _init(ks[5], (w, gn), gn ** -0.5, cfg.cdtype),
        "cw_c": _init(ks[5], (w, gn), gn ** -0.5, cfg.cdtype),
        "cb_x": jnp.zeros((di,), cfg.cdtype),
        "cb_b": jnp.zeros((gn,), cfg.cdtype),
        "cb_c": jnp.zeros((gn,), cfg.cdtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(F32),  # inv softplus
        "w_dt": _init(ks[7], (d, h), s, cfg.cdtype),
        "a_log": jnp.zeros((h,), F32),                            # A = -exp(.)
        "d_skip": jnp.ones((h,), F32),
        "norm_scale": jnp.ones((di,), F32),
        "w_out": _init(ks[0], (di, d), di ** -0.5, cfg.cdtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted slices. x: (B,S,C), w: (wlen,C)."""
    wlen = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (wlen - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(wlen))
    return jax.nn.silu(out + b)


def _segsum(dA: jax.Array) -> jax.Array:
    """dA: (..., Q) -> (..., Q, Q) with T[i, j] = sum_{j<k<=i} dA_k (i >= j)."""
    cum = jnp.cumsum(dA, axis=-1)
    T = cum[..., :, None] - cum[..., None, :]
    Q = dA.shape[-1]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, T, -jnp.inf)


def _chunk_local(xr, dtr, dAr, Br, Cr, hpg: int) -> jax.Array:
    """Within-chunk quadratic block (pure-jnp reference path).
    xr: (B,nc,Q,H,P), dtr/dAr: (B,nc,Q,H), Br/Cr: (B,nc,Q,G,N)."""
    L = jnp.exp(_segsum(dAr.transpose(0, 1, 3, 2)))            # (B,nc,H,Q,Q)
    Bh = jnp.repeat(Br, hpg, axis=3)
    Ch = jnp.repeat(Cr, hpg, axis=3)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)          # (B,nc,H,Q,Q)
    M = scores * L * dtr.transpose(0, 1, 3, 2)[:, :, :, None, :]
    return jnp.einsum("bchqk,bckhp->bcqhp", M, xr)


def ssd_chunked(x, dt, a_log, Bm, Cm, chunk: int, kernel_fn=None,
                return_state: bool = False):
    """SSD over a full sequence.

    x: (B,S,H,P); dt: (B,S,H) post-softplus; a_log: (H,); Bm/Cm: (B,S,G,N).
    Returns y (B,S,H,P) fp32 (and the final state if requested).
    ``kernel_fn`` optionally replaces the within-chunk computation with the
    Pallas kernel (repro.kernels.ops.ssd_chunk)."""
    Bsz, S, H, P = x.shape
    G = Bm.shape[2]
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        # pad the tail: dt=0 => decay exp(0)=1 and zero input contribution,
        # so real positions and the final state are unaffected (causal)
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // Q
    hpg = H // G

    A = -jnp.exp(a_log)
    dA = dt.astype(F32) * A                                    # (B,S,H)
    xr = x.astype(F32).reshape(Bsz, nc, Q, H, P)
    dAr = dA.reshape(Bsz, nc, Q, H)
    dtr = dt.astype(F32).reshape(Bsz, nc, Q, H)
    Br = Bm.astype(F32).reshape(Bsz, nc, Q, G, N)
    Cr = Cm.astype(F32).reshape(Bsz, nc, Q, G, N)

    cum = jnp.cumsum(dAr, axis=2)

    # 1. diagonal (within-chunk) blocks
    local = kernel_fn if kernel_fn is not None else _chunk_local
    y_diag = local(xr, dtr, dAr, Br, Cr, hpg)

    # 2. per-chunk end states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)
    xw = xr * (dtr * decay_to_end)[..., None]
    Bh = jnp.repeat(Br, hpg, axis=3)
    states = jnp.einsum("bcqhn,bcqhp->bchpn", Bh, xw)          # (B,nc,H,P,N)

    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # (B,nc,H)

    def scan_body(carry, xs):
        st, dec = xs
        return carry * dec[:, :, None, None] + st, carry       # emit incoming

    final, prev = jax.lax.scan(
        scan_body, jnp.zeros((Bsz, H, P, N), F32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev = prev.transpose(1, 0, 2, 3, 4)

    # 4. off-diagonal contribution
    Ch = jnp.repeat(Cr, hpg, axis=3)
    y_off = jnp.einsum("bcqhn,bchpn->bcqhp", Ch * jnp.exp(cum)[..., None], prev)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)[:, :S_orig]
    if return_state:
        return y, final
    return y


def _project(p, cfg, x):
    """x: (B,S,D) -> (z, xs_pre, b_pre, c_pre, dt) pre-conv projections."""
    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    b = x @ p["w_b"]
    c = x @ p["w_c"]
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(F32) + p["dt_bias"])
    return z, xs, b, c, dt


def _gate_norm_out(p, cfg, y, z):
    y = y * jax.nn.silu(z.astype(F32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm_scale"]
    return y.astype(cfg.cdtype) @ p["w_out"]


def mamba_forward(p: dict, cfg: ModelConfig, x: jax.Array, kernel_fn=None,
                  return_cache: bool = False):
    """Full-sequence Mamba2 block. x: (B, S, D)."""
    B, S, _ = x.shape
    H, P, G, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    z, xs_pre, b_pre, c_pre, dt = _project(p, cfg, x)
    xs = constrain(_causal_conv(xs_pre, p["cw_x"], p["cb_x"]),
                   "batch", None, "model").reshape(B, S, H, P)
    Bm = _causal_conv(b_pre, p["cw_b"], p["cb_b"]).reshape(B, S, G, N)
    Cm = _causal_conv(c_pre, p["cw_c"], p["cb_c"]).reshape(B, S, G, N)
    res = ssd_chunked(xs, dt, p["a_log"], Bm, Cm, cfg.ssm_chunk, kernel_fn,
                      return_state=return_cache)
    y, final = res if return_cache else (res, None)
    y = y + p["d_skip"][:, None] * xs.astype(F32)
    out = _gate_norm_out(p, cfg, y.reshape(B, S, cfg.d_inner), z)
    if return_cache:
        w1 = cfg.ssm_conv - 1
        cache = {"state": final,
                 "conv_x": xs_pre[:, -w1:].astype(cfg.cdtype),
                 "conv_b": b_pre[:, -w1:].astype(cfg.cdtype),
                 "conv_c": c_pre[:, -w1:].astype(cfg.cdtype)}
        return out, cache
    return out


def init_ssm_cache(cfg: ModelConfig, batch: int) -> dict:
    w1 = cfg.ssm_conv - 1
    gn = cfg.ssm_groups * cfg.ssm_state
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), F32),
        "conv_x": jnp.zeros((batch, w1, cfg.d_inner), cfg.cdtype),
        "conv_b": jnp.zeros((batch, w1, gn), cfg.cdtype),
        "conv_c": jnp.zeros((batch, w1, gn), cfg.cdtype),
    }


def _conv_step(window_prev, new, w, b):
    """window_prev: (B, wlen-1, C); new: (B, C) -> (out (B, C), new window)."""
    window = jnp.concatenate([window_prev, new[:, None]], axis=1)
    out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window.astype(F32),
                                 w.astype(F32)) + b.astype(F32))
    return out, window[:, 1:]


def mamba_decode_step(p: dict, cfg: ModelConfig, x: jax.Array,
                      cache: dict) -> tuple[jax.Array, dict]:
    """One-token recurrent step. x: (B, 1, D)."""
    B = x.shape[0]
    H, P, G, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    x1 = x[:, 0]
    z = x1 @ p["w_z"]
    dt = jax.nn.softplus((x1 @ p["w_dt"]).astype(F32) + p["dt_bias"])  # (B,H)
    xs, ncx = _conv_step(cache["conv_x"], x1 @ p["w_x"], p["cw_x"], p["cb_x"])
    Bm, ncb = _conv_step(cache["conv_b"], x1 @ p["w_b"], p["cw_b"], p["cb_b"])
    Cm, ncc = _conv_step(cache["conv_c"], x1 @ p["w_c"], p["cw_c"], p["cb_c"])
    xs = xs.reshape(B, H, P)
    Bm = Bm.reshape(B, G, N)
    Cm = Cm.reshape(B, G, N)
    A = -jnp.exp(p["a_log"])
    dA = jnp.exp(dt * A)
    Bh = jnp.repeat(Bm, H // G, axis=1)
    Ch = jnp.repeat(Cm, H // G, axis=1)
    st = cache["state"] * dA[..., None, None] \
        + (dt[..., None] * xs)[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", st, Ch) + p["d_skip"][:, None] * xs
    out = _gate_norm_out(p, cfg, y.reshape(B, cfg.d_inner), z)[:, None]
    return out, {"state": st, "conv_x": ncx.astype(cfg.cdtype),
                 "conv_b": ncb.astype(cfg.cdtype),
                 "conv_c": ncc.astype(cfg.cdtype)}
