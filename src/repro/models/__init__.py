from . import api, attention, base, encdec, layers, moe, smallnets, ssm, \
    transformer  # noqa
