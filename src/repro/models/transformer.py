"""Unified decoder stack for all assigned decoder-only architectures.

A model is ``cfg.n_blocks`` repetitions of ``cfg.pattern`` (a tuple of
``(mixer, ffn)`` sub-layers).  Block parameters are stacked on a leading
``n_blocks`` axis and executed under ``jax.lax.scan`` (small HLO, fast
multi-pod compiles) with per-block activation remat during training.

Execution modes:
  * ``lm_logits``    - full-sequence logits (training loss / DS-FL prediction)
  * ``prefill``      - full-sequence forward that also builds the decode cache
  * ``decode_step``  - one token against a ring-buffer KV cache / SSM state
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .base import ModelConfig
from .attention import (attn_decode_step, attn_forward, flash_attention,
                        init_attn, init_kv_cache, qkv_proj)
from .layers import (apply_rope, embed, init_embed, init_mlp, init_rmsnorm,
                     mlp, rmsnorm, rope_freqs, unembed)
from .moe import init_moe, moe_ffn
from .ssm import (init_mamba, init_ssm_cache, mamba_decode_step, mamba_forward)
from .shardctx import constrain


# ------------------------------------------------------------------- init ----
def _init_block(key, cfg: ModelConfig) -> dict:
    p = {}
    for i, (mixer, ffn) in enumerate(cfg.pattern):
        k1, k2, key = jax.random.split(key, 3)
        p[f"s{i}_n1"] = init_rmsnorm(cfg.d_model)
        p[f"s{i}_mix"] = init_attn(k1, cfg) if mixer == "attn" else init_mamba(k1, cfg)
        if ffn != "none":
            p[f"s{i}_n2"] = init_rmsnorm(cfg.d_model)
            p[f"s{i}_ffn"] = init_moe(k2, cfg) if ffn == "moe" else init_mlp(k2, cfg)
    return p


def init_lm(cfg: ModelConfig, key) -> dict:
    ke, kb, kp = jax.random.split(key, 3)
    blocks = jax.vmap(lambda k: _init_block(k, cfg))(
        jax.random.split(kb, cfg.n_blocks))
    params = {"embed": init_embed(ke, cfg),
              "blocks": blocks,
              "final_norm": init_rmsnorm(cfg.d_model)}
    if cfg.n_patches:   # VLM: projector stub from frozen vision tower (stub)
        params["patch_proj"] = {
            "w": (jax.random.normal(kp, (cfg.d_model, cfg.d_model), jnp.float32)
                  * cfg.d_model ** -0.5).astype(cfg.cdtype)}
    return params


# --------------------------------------------------------------- forward ----
def _block_forward(cfg: ModelConfig, bp: dict, x: jax.Array, positions,
                   q_chunk: int, kv_chunk: int, use_ssd_kernel: bool = False,
                   sublayer_remat: bool = False):
    """One pattern-repeat in full-sequence mode.  Returns (x, aux).
    With ``sublayer_remat`` every mixer/FFN is its own checkpoint region, so
    the backward peak holds one sub-layer's intermediates, not the whole
    pattern-repeat's (matters for Jamba's 8-sub-layer blocks)."""
    aux = jnp.zeros((), jnp.float32)
    kernel_fn = None
    if use_ssd_kernel:
        from repro.kernels import ops as kops
        kernel_fn = kops.ssd_chunk
    ckpt = jax.checkpoint if sublayer_remat else (lambda f: f)
    for i, (mixer, ffn) in enumerate(cfg.pattern):
        h = rmsnorm(bp[f"s{i}_n1"], x, cfg.norm_eps)
        if mixer == "attn":
            out = ckpt(lambda p_, h_: attn_forward(
                p_, cfg, h_, positions=positions, q_chunk=q_chunk,
                kv_chunk=kv_chunk))(bp[f"s{i}_mix"], h)
        else:
            out = ckpt(lambda p_, h_: mamba_forward(
                p_, cfg, h_, kernel_fn=kernel_fn))(bp[f"s{i}_mix"], h)
        x = constrain(x + out, "batch", None, None)
        if ffn != "none":
            h = rmsnorm(bp[f"s{i}_n2"], x, cfg.norm_eps)
            if ffn == "moe":
                out, a = ckpt(lambda p_, h_: moe_ffn(p_, cfg, h_))(
                    bp[f"s{i}_ffn"], h)
                aux = aux + a
            else:
                out = ckpt(lambda p_, h_: mlp(p_, cfg, h_))(bp[f"s{i}_ffn"], h)
            x = constrain(x + out, "batch", None, None)
    return x, aux


def backbone(cfg: ModelConfig, params: dict, x: jax.Array, *, remat: bool = True,
             positions=None) -> tuple[jax.Array, jax.Array]:
    """Run the scanned block stack on embeddings x: (B, S, D)."""
    S = x.shape[1]
    q_chunk = kv_chunk = 1024 if S >= 2048 else S
    base_f = functools.partial(_block_forward, cfg, positions=positions,
                               q_chunk=q_chunk, kv_chunk=kv_chunk,
                               sublayer_remat=remat and len(cfg.pattern) > 1)
    f = jax.checkpoint(base_f) if remat else base_f

    def body(carry, bp):
        h, aux = carry
        h, a = f(bp, h)
        return (h, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"],
                               unroll=cfg.n_blocks if cfg.scan_unroll else 1)
    return x, aux


def embed_inputs(cfg: ModelConfig, params: dict, tokens: jax.Array,
                 extra_embeds=None) -> jax.Array:
    """Token embedding; VLM prepends (stub) patch embeddings through the
    projector.  extra_embeds: (B, S_img, D) precomputed patch features."""
    x = embed(params["embed"], cfg, tokens)
    if extra_embeds is not None:
        pe = extra_embeds.astype(cfg.cdtype) @ params["patch_proj"]["w"]
        x = jnp.concatenate([pe, x], axis=1)
    return x


def lm_logits(cfg: ModelConfig, params: dict, tokens: jax.Array,
              extra_embeds=None, remat: bool = True) -> jax.Array:
    """Full-sequence logits (B, S_text, V).  VLM image positions are dropped
    from the output (loss/distillation is on text tokens)."""
    x = embed_inputs(cfg, params, tokens, extra_embeds)
    x, aux = backbone(cfg, params, x, remat=remat)
    if extra_embeds is not None:
        x = x[:, extra_embeds.shape[1]:]
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = constrain(unembed(params["embed"], cfg, x),
                       "batch", None, "model")
    return logits, aux


# ----------------------------------------------------------------- decode ----
def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Decode cache for `seq_len` context.  Attention sub-layers get a ring
    buffer of min(seq_len, sliding_window); mamba sub-layers O(1) state."""
    cache = {}
    for i, (mixer, _) in enumerate(cfg.pattern):
        if mixer == "attn":
            W = min(seq_len, cfg.sliding_window or seq_len)
            one = init_kv_cache(cfg, batch, W)
        else:
            one = init_ssm_cache(cfg, batch)
        cache[f"s{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_blocks,) + a.shape), one)
    return cache


def _block_decode(cfg: ModelConfig, bp: dict, bc: dict, x: jax.Array, pos):
    new_cache = {}
    for i, (mixer, ffn) in enumerate(cfg.pattern):
        h = rmsnorm(bp[f"s{i}_n1"], x, cfg.norm_eps)
        if mixer == "attn":
            out, nc = attn_decode_step(bp[f"s{i}_mix"], cfg, h, bc[f"s{i}"], pos)
        else:
            out, nc = mamba_decode_step(bp[f"s{i}_mix"], cfg, h, bc[f"s{i}"])
        new_cache[f"s{i}"] = nc
        x = x + out
        if ffn != "none":
            h = rmsnorm(bp[f"s{i}_n2"], x, cfg.norm_eps)
            if ffn == "moe":
                out, _ = moe_ffn(bp[f"s{i}_ffn"], cfg, h)
            else:
                out = mlp(bp[f"s{i}_ffn"], cfg, h)
            x = x + out
    return x, new_cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, token: jax.Array,
                pos: jax.Array) -> tuple[jax.Array, dict]:
    """One decode step.  token: (B,) int32; pos: scalar int32 position.
    Returns (logits (B, V), new_cache)."""
    x = embed(params["embed"], cfg, token[:, None])

    def body(h, xs):
        bp, bc = xs
        h, nc = _block_decode(cfg, bp, bc, h, pos)
        return h, nc

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache),
                                unroll=cfg.n_blocks if cfg.scan_unroll else 1)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = constrain(unembed(params["embed"], cfg, x), "batch", None, "model")
    return logits[:, 0], new_cache


# ---------------------------------------------------------------- prefill ----
def _block_prefill(cfg: ModelConfig, bp: dict, x: jax.Array, positions,
                   seq_len: int):
    """Full-seq forward that also emits this block's decode cache."""
    cache = {}
    B, S, _ = x.shape
    for i, (mixer, ffn) in enumerate(cfg.pattern):
        h = rmsnorm(bp[f"s{i}_n1"], x, cfg.norm_eps)
        if mixer == "attn":
            W = min(seq_len, cfg.sliding_window or seq_len)
            q, k, v = qkv_proj(bp[f"s{i}_mix"], cfg, h)
            if cfg.pos_embed == "rope":
                cos, sin = rope_freqs(cfg, positions)
                q = apply_rope(q, cos, sin)
                k = apply_rope(k, cos, sin)
            o = flash_attention(q, k, v, causal=True,
                                window=cfg.sliding_window,
                                q_chunk=min(1024, S), kv_chunk=min(1024, S))
            out = o.reshape(B, S, cfg.n_heads * cfg.hd) @ bp[f"s{i}_mix"]["wo"]
            # ring-buffer layout: slot t % W holds token t of the last W
            kl, vl = k[:, -W:], v[:, -W:]
            if S >= W:
                shift = S % W
                kl = jnp.roll(kl, shift, axis=1)
                vl = jnp.roll(vl, shift, axis=1)
                cache[f"s{i}"] = {"k": kl, "v": vl}
            else:
                pad = W - S
                z = jnp.zeros((B, pad, cfg.n_kv_heads, cfg.hd), k.dtype)
                cache[f"s{i}"] = {"k": jnp.concatenate([kl, z], 1),
                                  "v": jnp.concatenate([vl, z], 1)}
        else:
            out, cache[f"s{i}"] = mamba_forward(bp[f"s{i}_mix"], cfg, h,
                                                return_cache=True)
        x = x + out
        if ffn != "none":
            h = rmsnorm(bp[f"s{i}_n2"], x, cfg.norm_eps)
            out = (moe_ffn(bp[f"s{i}_ffn"], cfg, h)[0] if ffn == "moe"
                   else mlp(bp[f"s{i}_ffn"], cfg, h))
            x = x + out
    return x, cache


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            extra_embeds=None, seq_len: int | None = None):
    """Prefill: returns (last-token logits (B, V), decode cache)."""
    B, S = tokens.shape
    if extra_embeds is not None:
        S = S + extra_embeds.shape[1]
    seq_len = seq_len or S
    x = embed_inputs(cfg, params, tokens, extra_embeds)
    positions = jnp.arange(S)

    def body(h, bp):
        h, cache = _block_prefill(cfg, bp, h, positions, seq_len)
        return h, cache

    x, cache = jax.lax.scan(body, x, params["blocks"],
                            unroll=cfg.n_blocks if cfg.scan_unroll else 1)
    x = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return unembed(params["embed"], cfg, x)[:, 0], cache
