"""Activation-sharding context.

Model code calls ``constrain(x, "batch", None, "model")`` at layer
boundaries; outside a launch context this is a no-op, inside it becomes
``with_sharding_constraint`` with the launcher's axis mapping.  Explicit
activation constraints stop GSPMD from "solving" FSDP weight shardings by
all-reducing activation-sized partial sums (observed: a 40 GB logits
all-reduce on qwen1.5-4b before constraints were added — see §Perf).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_tls = threading.local()


def _state():
    if not hasattr(_tls, "ctx"):
        _tls.ctx = None
    return _tls.ctx


@contextlib.contextmanager
def axis_ctx(mesh, batch_axes=("data",), model_axis="model"):
    """Launcher context: axis names + sizes for divisibility guards."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    prev = _state()
    _tls.ctx = {
        "mesh": mesh,
        "batch": tuple(batch_axes),
        "batch_size": 1,
        "model": model_axis,
        "model_size": sizes.get(model_axis, 1),
    }
    for a in batch_axes:
        _tls.ctx["batch_size"] *= sizes.get(a, 1)
    try:
        yield
    finally:
        _tls.ctx = prev


def constrain(x: jax.Array, *dims):
    """dims: "batch" | "model" | None per array axis.  Divisibility-guarded;
    no-op without an active context."""
    ctx = _state()
    if ctx is None:
        return x
    spec = []
    for d, size in zip(dims, x.shape):
        if d == "batch" and size % ctx["batch_size"] == 0 and ctx["batch_size"] > 1:
            spec.append(ctx["batch"] if len(ctx["batch"]) > 1 else ctx["batch"][0])
        elif d == "model" and size % ctx["model_size"] == 0 and ctx["model_size"] > 1:
            spec.append(ctx["model"])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(ctx["mesh"], P(*spec)))
