"""Uniform model API across all architecture families.

``batch`` dicts carry:  tokens (B, S) int32 — always;
patches (B, P, D) — vlm stub embeddings;  frames (B, F, D) — audio stub.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import encdec as ED
from . import transformer as T
from .base import ModelConfig


def model_init(cfg: ModelConfig, key) -> dict:
    if cfg.arch_type == "audio":
        return ED.init_encdec(cfg, key)
    return T.init_lm(cfg, key)


def model_logits(cfg: ModelConfig, params: dict, batch: dict,
                 remat: bool = True):
    """Full-sequence logits + aux (MoE load-balance) for train / prediction."""
    if cfg.arch_type == "audio":
        return ED.encdec_lm_logits(cfg, params, batch["tokens"],
                                   batch["frames"], remat)
    extra = batch.get("patches")
    return T.lm_logits(cfg, params, batch["tokens"], extra, remat)


def model_init_cache(cfg: ModelConfig, params: dict, batch_size: int,
                     seq_len: int, batch: dict | None = None) -> dict:
    if cfg.arch_type == "audio":
        enc_out = ED.encode(cfg, params, batch["frames"], remat=False)
        return ED.init_encdec_cache(cfg, params, batch_size, seq_len, enc_out)
    return T.init_cache(cfg, batch_size, seq_len)


def model_decode_step(cfg: ModelConfig, params: dict, cache: dict,
                      token: jax.Array, pos: jax.Array):
    if cfg.arch_type == "audio":
        return ED.encdec_decode_step(cfg, params, cache, token, pos)
    return T.decode_step(cfg, params, cache, token, pos)


def model_prefill(cfg: ModelConfig, params: dict, batch: dict,
                  seq_len: int | None = None):
    if cfg.arch_type == "audio":
        enc_out = ED.encode(cfg, params, batch["frames"], remat=False)
        logits = ED.decoder_logits(cfg, params, batch["tokens"], enc_out,
                                   remat=False)
        cache = ED.init_encdec_cache(cfg, params, batch["tokens"].shape[0],
                                     seq_len or batch["tokens"].shape[1],
                                     enc_out)
        return logits[:, -1], cache
    return T.prefill(cfg, params, batch["tokens"], batch.get("patches"),
                     seq_len)
