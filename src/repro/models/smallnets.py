"""The paper's exact evaluation models (Section 4.1), in pure JAX.

Parameter counts are verified against the paper (Keras conventions: conv/dense
biases, BatchNorm counted as 4 params/channel incl. moving statistics):

  * MNIST CNN      — paper: 583,242   (ours: 582,410, valid-padding; 0.14% delta)
  * F-MNIST CNN    — paper: 2,760,228 (ours: 2,759,976; 0.01% delta)
  * IMDb LSTM      — paper: 646,338   (ours: 648,386 at vocab 20k; 0.3% delta)
  * Reuters DNN    — paper: 5,194,670 (ours: 5,194,670; EXACT)

Models are functional: ``init(key) -> (params, state)``;
``apply(params, state, x, train) -> (logits, new_state)`` where ``state``
carries BatchNorm running statistics (aggregated by FedAvg like any leaf).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp


def _dense(key, n_in, n_out):
    w = jax.random.normal(key, (n_in, n_out), jnp.float32) * (2.0 / n_in) ** 0.5
    return {"w": w, "b": jnp.zeros((n_out,), jnp.float32)}


def _conv(key, kh, kw, cin, cout):
    w = jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) \
        * (2.0 / (kh * kw * cin)) ** 0.5
    return {"w": w, "b": jnp.zeros((cout,), jnp.float32)}


def _bn(c):
    return ({"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))},
            {"mean": jnp.zeros((c,)), "var": jnp.ones((c,))})


def conv2d(p, x, padding="VALID"):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (1, 1), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def batchnorm(p, s, x, train: bool, momentum=0.9, eps=1e-5):
    axes = tuple(range(x.ndim - 1))
    if train:
        m = jnp.mean(x, axes)
        v = jnp.var(x, axes)
        ns = {"mean": momentum * s["mean"] + (1 - momentum) * m,
              "var": momentum * s["var"] + (1 - momentum) * v}
    else:
        m, v, ns = s["mean"], s["var"], s
    y = (x - m) * jax.lax.rsqrt(v + eps) * p["scale"] + p["bias"]
    return y, ns


def maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


# -------------------------------------------------------------- MNIST CNN ----
def init_mnist_cnn(key, n_classes=10, image_hw=28, widths=(32, 64), fc=512):
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["c1"] = _conv(ks[0], 5, 5, 1, widths[0])
    p["bn1"], s["bn1"] = _bn(widths[0])
    p["c2"] = _conv(ks[1], 5, 5, widths[0], widths[1])
    p["bn2"], s["bn2"] = _bn(widths[1])
    hw = ((image_hw - 4) // 2 - 4) // 2      # two valid 5x5 convs + two pools
    p["d1"] = _dense(ks[2], hw * hw * widths[1], fc)
    p["d2"] = _dense(ks[3], fc, n_classes)
    return p, s


def apply_mnist_cnn(p, s, x, train: bool):
    ns = {}
    h = conv2d(p["c1"], x)
    h, ns["bn1"] = batchnorm(p["bn1"], s["bn1"], h, train)
    h = maxpool2(jax.nn.relu(h))
    h = conv2d(p["c2"], h)
    h, ns["bn2"] = batchnorm(p["bn2"], s["bn2"], h, train)
    h = maxpool2(jax.nn.relu(h))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p["d1"]["w"] + p["d1"]["b"])
    return h @ p["d2"]["w"] + p["d2"]["b"], ns


# ------------------------------------------------------------ F-MNIST CNN ----
_FM_WIDTHS = (32, 32, 64, 64, 128, 128)


def init_fmnist_cnn(key, n_classes=10, image_hw=28, fc=(382, 192)):
    ks = jax.random.split(key, 9)
    p, s = {}, {}
    cin = 1
    for i, c in enumerate(_FM_WIDTHS):
        p[f"c{i}"] = _conv(ks[i], 3, 3, cin, c)
        p[f"bn{i}"], s[f"bn{i}"] = _bn(c)
        cin = c
    hw = image_hw // 4                       # 'same' convs; pools after pairs 1,2
    flat = hw * hw * _FM_WIDTHS[-1]
    p["d1"] = _dense(ks[6], flat, fc[0])
    p["d2"] = _dense(ks[7], fc[0], fc[1])
    p["d3"] = _dense(ks[8], fc[1], n_classes)
    return p, s


def apply_fmnist_cnn(p, s, x, train: bool):
    ns = {}
    h = x
    for i in range(6):
        h = conv2d(p[f"c{i}"], h, padding="SAME")
        h, ns[f"bn{i}"] = batchnorm(p[f"bn{i}"], s[f"bn{i}"], h, train)
        h = jax.nn.relu(h)
        if i in (1, 3):                      # pools after conv pairs 1 and 2
            h = maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p["d1"]["w"] + p["d1"]["b"])
    h = jax.nn.relu(h @ p["d2"]["w"] + p["d2"]["b"])
    return h @ p["d3"]["w"] + p["d3"]["b"], ns


# -------------------------------------------------------------- IMDb LSTM ----
def init_imdb_lstm(key, vocab=20_000, emb=32, hidden=32, n_classes=2):
    ks = jax.random.split(key, 4)
    return {
        "embed": jax.random.normal(ks[0], (vocab, emb)) * 0.05,
        "wx": jax.random.normal(ks[1], (emb, 4 * hidden)) * emb ** -0.5,
        "wh": jax.random.normal(ks[2], (hidden, 4 * hidden)) * hidden ** -0.5,
        "b": jnp.zeros((4 * hidden,)),
        "out": _dense(ks[3], hidden, n_classes),
    }, {}


def apply_imdb_lstm(p, s, tokens, train: bool):
    """tokens: (B, S) int32.  Final-state LSTM -> dense."""
    x = jnp.take(p["embed"], tokens, axis=0)     # (B, S, E)
    B = x.shape[0]
    H = p["wh"].shape[0]

    def step(carry, xt):
        h, c = carry
        z = xt @ p["wx"] + h @ p["wh"] + p["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    init = (jnp.zeros((B, H)), jnp.zeros((B, H)))
    (h, _), _ = jax.lax.scan(step, init, x.transpose(1, 0, 2))
    return h @ p["out"]["w"] + p["out"]["b"], s


# ---------------------------------------------------------------- tiny MLP ---
def init_tiny_mlp(key, n_classes=10, image_hw=16, hidden=32):
    """Beyond-paper micro model for simulation smoke runs: flatten -> dense
    -> relu -> dense.  Small enough that a 100-client fleet jits in seconds
    on CPU (see benchmarks/time_to_accuracy.py, examples/sim_stragglers.py)."""
    k1, k2 = jax.random.split(key)
    return {"d1": _dense(k1, image_hw * image_hw, hidden),
            "d2": _dense(k2, hidden, n_classes)}, {}


def apply_tiny_mlp(p, s, x, train: bool):
    h = x.reshape(x.shape[0], -1)
    h = jax.nn.relu(h @ p["d1"]["w"] + p["d1"]["b"])
    return h @ p["d2"]["w"] + p["d2"]["b"], s


# ----------------------------------------------------------- Reuters DNN -----
def init_reuters_dnn(key, vocab=10_000, n_classes=46, widths=(512, 128)):
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["d1"] = _dense(ks[0], vocab, widths[0])
    p["bn1"], s["bn1"] = _bn(widths[0])
    p["d2"] = _dense(ks[1], widths[0], widths[1])
    p["bn2"], s["bn2"] = _bn(widths[1])
    p["d3"] = _dense(ks[2], widths[1], n_classes)
    return p, s


def apply_reuters_dnn(p, s, x, train: bool):
    ns = {}
    h = x @ p["d1"]["w"] + p["d1"]["b"]
    h, ns["bn1"] = batchnorm(p["bn1"], s["bn1"], h, train)
    h = jax.nn.relu(h)
    h = h @ p["d2"]["w"] + p["d2"]["b"]
    h, ns["bn2"] = batchnorm(p["bn2"], s["bn2"], h, train)
    h = jax.nn.relu(h)
    return h @ p["d3"]["w"] + p["d3"]["b"], ns


# ---------------------------------------------------- registry & factories ---
@dataclass(frozen=True)
class SmallNet:
    name: str
    init: callable
    apply: callable
    input_kind: str          # image | tokens | bow
    n_classes: int


def make_smallnet(name: str, **kw) -> SmallNet:
    if name == "mnist_cnn":
        return SmallNet("mnist_cnn", functools.partial(init_mnist_cnn, **kw),
                        apply_mnist_cnn, "image", kw.get("n_classes", 10))
    if name == "fmnist_cnn":
        return SmallNet("fmnist_cnn", functools.partial(init_fmnist_cnn, **kw),
                        apply_fmnist_cnn, "image", kw.get("n_classes", 10))
    if name == "imdb_lstm":
        return SmallNet("imdb_lstm", functools.partial(init_imdb_lstm, **kw),
                        apply_imdb_lstm, "tokens", kw.get("n_classes", 2))
    if name == "reuters_dnn":
        return SmallNet("reuters_dnn", functools.partial(init_reuters_dnn, **kw),
                        apply_reuters_dnn, "bow", kw.get("n_classes", 46))
    if name == "tiny_mlp":
        return SmallNet("tiny_mlp", functools.partial(init_tiny_mlp, **kw),
                        apply_tiny_mlp, "image", kw.get("n_classes", 10))
    raise ValueError(name)
