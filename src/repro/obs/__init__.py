"""`repro.obs` — one telemetry plane for train, sim, and serve.

Four pieces, all zero-overhead when disabled and all host-side only (no
instrumentation ever runs inside a jitted program, so bitwise parity and
the one-host-sync-per-chunk discipline are untouched — pinned by
``tests/test_obs.py``):

* `Tracer` (`trace.py`) — structured JSONL span/event records with
  monotonic host timestamps, pid/tid, and nesting via context managers.
  `perfetto.py` exports a trace to Chrome/Perfetto ``trace_event`` JSON so
  a whole run — engine chunks, cohort slab gather/scatter, wire
  measurement, serve prefill/decode, weight hot-swaps, XLA compiles —
  renders on one timeline (``ui.perfetto.dev``).
* `MetricsRegistry` (`metrics.py`) — counters, gauges, and fixed-bucket
  histograms with percentile estimates, snapshottable to JSON.  The
  engine, sim runners, schedulers, client store, serve engine, and
  admission queue all publish into the installed registry.
* `JitCacheWatch` (`jit_watch.py`) — compile/retrace accounting: every
  XLA backend compile is recorded (and traced), tracked jitted callables
  report per-function cache sizes, and ``assert_no_new_compiles`` turns
  "no recompiles after warmup" into a checkable invariant.
* `RunProvenance` (`provenance.py`) — git sha, jax/jaxlib versions,
  platform, x64, kernel interpret mode — stamped into every trace header,
  metrics snapshot, and ``BENCH_*.json`` so numbers are interpretable
  across machines.

The module-level `tracing`/`metrics` globals are the thread-through
points: library code calls ``obs.span(...)`` / ``obs.current_registry()``
unconditionally; with nothing installed these cost one global read and
allocate nothing.
"""
from .jit_watch import JitCacheWatch, engine_compile_counts  # noqa: F401
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,  # noqa
                      percentile, percentiles)
from .provenance import RunProvenance  # noqa: F401
from .trace import (Tracer, current_registry, enabled, event,  # noqa
                    install, install_registry, instant, span, start, stop,
                    trace_to)

__all__ = [
    "Counter", "Gauge", "Histogram", "JitCacheWatch", "MetricsRegistry",
    "RunProvenance", "Tracer", "current_registry", "enabled",
    "engine_compile_counts", "event", "install", "install_registry",
    "instant", "percentile", "percentiles", "span", "start", "stop",
    "trace_to",
]
