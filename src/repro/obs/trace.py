"""Structured JSONL tracing: spans, instants, and the global install point.

A `Tracer` appends one JSON object per line to a file:

* header (first line): ``{"type": "meta", "clock": "perf_counter_ns",
  "t0_ns": ..., "wall_iso": ..., "provenance": {...}}`` — the provenance
  stamp every trace carries (`RunProvenance`).
* spans: ``{"type": "span", "name", "cat", "ts_us", "dur_us", "pid",
  "tid", "args"}`` — closed intervals, written at span exit.  Timestamps
  are microseconds of monotonic host time since the header's ``t0_ns``,
  so records are orderable within a run and nest by containment (which is
  exactly how Perfetto renders same-tid "X" events).
* instants: same shape, no ``dur_us``.

Nothing here touches jax: spans measure *host-visible* phases (a jitted
call's span covers dispatch-to-sync, which is the number serving/training
actually waits on).  Instrumented libraries call the module-level
``span``/``event``/``instant`` helpers, which hit the process-global
tracer installed by ``start``/``install``/``trace_to`` — with none
installed they return a shared no-op context manager: one global read,
zero allocation, no timestamps taken (the zero-overhead-when-disabled
contract, parity-pinned in ``tests/test_obs.py``).
"""
from __future__ import annotations

import datetime
import json
import os
import threading
import time
from typing import Optional

# span categories the exporters group by; free-form strings are fine too,
# these are just the layers the built-in instrumentation uses
CATEGORIES = ("engine", "sim", "cohort", "wire", "serve", "queue", "swap",
              "jit", "app")


class _NullSpan:
    """The disabled path: a reusable, stateless no-op context manager."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args):            # parity with _Span.set
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tracer", "name", "cat", "args", "t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = 0

    def set(self, **args):
        """Attach result attributes discovered inside the span."""
        self.args.update(args)
        return self

    def __enter__(self):
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        self.tracer._write_span(self.name, self.cat, self.t0, t1, self.args)
        return False


class Tracer:
    """JSONL span/event writer.  One per output file; cheap enough to wrap
    every host-side phase of a run (a span costs two ``perf_counter_ns``
    reads and one buffered ``json.dumps`` line)."""

    def __init__(self, path: str, provenance: Optional[dict] = None,
                 buffer_lines: int = 256):
        self.path = path
        self._f = open(path, "w")
        self._lock = threading.Lock()
        self._buf: list = []
        self._buffer_lines = int(buffer_lines)
        self.t0_ns = time.perf_counter_ns()
        self.n_records = 0
        if provenance is None:
            from .provenance import RunProvenance
            provenance = RunProvenance.collect().asdict()
        self._emit({"type": "meta", "clock": "perf_counter_ns",
                    "t0_ns": self.t0_ns,
                    "wall_iso": datetime.datetime.now(
                        datetime.timezone.utc).isoformat(),
                    "provenance": provenance})

    # ------------------------------------------------------------ writing ----
    def _emit(self, rec: dict) -> None:
        line = json.dumps(rec, default=str)
        with self._lock:
            self._buf.append(line)
            self.n_records += 1
            if len(self._buf) >= self._buffer_lines:
                self._flush_locked()

    def _flush_locked(self) -> None:
        if self._buf:
            self._f.write("\n".join(self._buf) + "\n")
            self._buf = []

    def flush(self) -> None:
        with self._lock:
            self._flush_locked()
            self._f.flush()

    def close(self) -> None:
        with self._lock:
            self._flush_locked()
            if not self._f.closed:
                self._f.close()

    def _us(self, t_ns: int) -> float:
        return (t_ns - self.t0_ns) / 1e3

    def _write_span(self, name, cat, t0_ns, t1_ns, args) -> None:
        self._emit({"type": "span", "name": name, "cat": cat,
                    "ts_us": self._us(t0_ns),
                    "dur_us": (t1_ns - t0_ns) / 1e3,
                    "pid": os.getpid(),
                    "tid": threading.get_native_id(),
                    **({"args": args} if args else {})})

    # --------------------------------------------------------------- API -----
    def span(self, name: str, cat: str = "app", **args) -> _Span:
        """``with tracer.span("engine.chunk", "engine", rounds=k): ...``"""
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "app", **args) -> None:
        t = time.perf_counter_ns()
        self._emit({"type": "instant", "name": name, "cat": cat,
                    "ts_us": self._us(t), "pid": os.getpid(),
                    "tid": threading.get_native_id(),
                    **({"args": args} if args else {})})


# ------------------------------------------------------------ global plane ---
_TRACER: Optional[Tracer] = None
_REGISTRY = None                      # Optional[MetricsRegistry]


def install(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Make ``tracer`` the process-global tracer (None disables tracing);
    returns the previous one so callers can restore it."""
    global _TRACER
    prev, _TRACER = _TRACER, tracer
    return prev


def start(path: str, provenance: Optional[dict] = None) -> Tracer:
    """Open a JSONL trace at ``path`` and install it globally.  Also
    registers the compile-event listener so XLA compiles land on the
    timeline (`jit_watch`)."""
    tracer = Tracer(path, provenance=provenance)
    install(tracer)
    from .jit_watch import ensure_listener
    ensure_listener()
    return tracer


def stop() -> None:
    """Close and uninstall the global tracer (no-op when none installed)."""
    global _TRACER
    if _TRACER is not None:
        _TRACER.close()
        _TRACER = None


def enabled() -> bool:
    return _TRACER is not None


def span(name: str, cat: str = "app", **args):
    """The library-side entry point: a real span when tracing is on, the
    shared no-op otherwise."""
    t = _TRACER
    return _NULL_SPAN if t is None else t.span(name, cat, **args)


def instant(name: str, cat: str = "app", **args) -> None:
    t = _TRACER
    if t is not None:
        t.instant(name, cat, **args)


# alias: a point-in-time record ("event" reads better at some call sites)
event = instant


class trace_to:
    """``with obs.trace_to("run.jsonl") as t: ...`` — scoped tracing that
    restores whatever tracer (usually none) was installed before."""

    def __init__(self, path: str, provenance: Optional[dict] = None):
        self.path = path
        self.provenance = provenance
        self.tracer: Optional[Tracer] = None
        self._prev: Optional[Tracer] = None

    def __enter__(self) -> Tracer:
        self.tracer = Tracer(self.path, provenance=self.provenance)
        self._prev = install(self.tracer)
        from .jit_watch import ensure_listener
        ensure_listener()
        return self.tracer

    def __exit__(self, *exc):
        install(self._prev)
        self.tracer.close()
        return False


# ------------------------------------------------------- metrics registry ----
def install_registry(registry) -> object:
    """Install a `MetricsRegistry` as the process-global publish target
    (None disables publishing); returns the previous one."""
    global _REGISTRY
    prev, _REGISTRY = _REGISTRY, registry
    return prev


def current_registry():
    """The installed `MetricsRegistry`, or None.  Library code reads this
    once per host-side phase (chunk / round / serve step) and skips
    publishing when it is None — the disabled path is one global read."""
    return _REGISTRY
