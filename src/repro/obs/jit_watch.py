"""Compile/retrace accounting: know exactly when XLA compiles something.

Recompiles are this repo's quietest performance bug: an eager op fed a new
batch shape, a ctx whose treedef flipped, a donated buffer placed wrong —
each silently re-traces and re-compiles, and the run is suddenly 100x
slower with bit-identical results.  `JitCacheWatch` turns that into data:

* every XLA backend compile fires a `jax.monitoring` event; an active
  watch records it (count + duration) and, when tracing is on, draws it
  as a ``cat="jit"`` span on the timeline — so "why is round 7 slow"
  is answered by looking;
* ``wrap(name, fn)`` instruments a specific jitted callable: after each
  call the cache size is polled, and growth is recorded with the call's
  arg treedef and timestamp — *which function, which structure, when*;
* ``mark()`` / ``assert_no_new_compiles()`` pin the steady state: CI
  warms a path up, marks, runs the real work, and asserts the jit caches
  never grew (`benchmarks/obs_smoke.py`).

The monitoring listener is registered once per process, lazily, and
dispatches to whichever watches are active — jax offers no per-listener
unregistration, so the listener itself is permanent but free when
nothing is listening.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

from . import trace as _trace

COMPILE_EVENT = "backend_compile"     # substring of the jax.monitoring event


def jit_cache_size(fn) -> int:
    """Number of programs a jitted callable has compiled (-1 if this jax
    version hides the API).  The no-recompile-after-warmup guarantees in
    serve and CI are asserted through this."""
    try:
        return fn._cache_size()
    except Exception:  # pragma: no cover - jax without the private API
        return -1


def engine_compile_counts(engine) -> dict:
    """Compiled-program accounting for a `core.engine.FedEngine`: how many
    distinct round/chunk signatures were built and how many programs their
    jits compiled (each treedef-keyed entry should sit at exactly 1 after
    warmup — more means something re-specialized underneath it)."""
    rounds = [jit_cache_size(f) for f in engine._round_cache.values()]
    chunks = [jit_cache_size(f) for f in engine._chunk_cache.values()]
    return {"round_signatures": len(rounds),
            "round_programs": sum(max(n, 0) for n in rounds),
            "chunk_signatures": len(chunks),
            "chunk_programs": sum(max(n, 0) for n in chunks)}


@dataclass
class CompileRecord:
    """One observed compilation."""
    kind: str                         # "xla" (monitoring) | "cache" (wrap)
    name: str                         # event name or wrapped-fn name
    t_ns: int                         # perf_counter_ns at observation
    duration_s: Optional[float] = None
    treedef: Optional[str] = None     # arg treedef (wrapped fns only)


# one process-global listener fanning out to the active watches
_WATCHES: list = []
_LISTENER_INSTALLED = False


def _on_event_duration(event: str, duration: float, **kw) -> None:
    if COMPILE_EVENT not in event:
        return
    t1 = time.perf_counter_ns()
    for w in _WATCHES:
        w._records.append(CompileRecord(kind="xla", name=event, t_ns=t1,
                                        duration_s=duration))
    tracer = _trace._TRACER
    if tracer is not None:
        # draw the compile as a block ending now (jax reports the duration
        # only on completion)
        tracer._write_span("xla.compile", "jit",
                           t1 - int(duration * 1e9), t1,
                           {"duration_ms": duration * 1e3})


def ensure_listener() -> None:
    """Register the monitoring listener (idempotent).  Called by watch
    activation and by `obs.start`, so compiles land on every trace."""
    global _LISTENER_INSTALLED
    if _LISTENER_INSTALLED:
        return
    try:
        import jax.monitoring
        jax.monitoring.register_event_duration_secs_listener(
            _on_event_duration)
        _LISTENER_INSTALLED = True
    except Exception:  # pragma: no cover - jax without monitoring
        pass


@dataclass(eq=False)              # identity semantics: watches live in a list
class JitCacheWatch:
    """Records every compilation observed while active.

    Use as a context manager (``with JitCacheWatch() as watch:``) or call
    ``start()``/``stop()``.  ``records`` accumulates `CompileRecord`s from
    the global XLA compile events plus any ``wrap``-instrumented
    callables; ``mark()`` snapshots the current count so
    ``new_since_mark()``/``assert_no_new_compiles()`` can pin a warmed-up
    steady state."""
    _records: list = field(default_factory=list)
    _wrapped: dict = field(default_factory=dict)   # name -> (fn, [last_size])
    _mark: int = 0

    # ---------------------------------------------------------- lifecycle ----
    def start(self) -> "JitCacheWatch":
        ensure_listener()
        if self not in _WATCHES:
            _WATCHES.append(self)
        return self

    def stop(self) -> None:
        if self in _WATCHES:
            _WATCHES.remove(self)

    def __enter__(self) -> "JitCacheWatch":
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False

    # ------------------------------------------------------------ records ----
    @property
    def records(self) -> list:
        return list(self._records)

    def compiles(self) -> int:
        """Total compilations observed since the watch started."""
        return len(self._records)

    def mark(self) -> int:
        """Declare warmup over: subsequent compiles are regressions."""
        self._mark = len(self._records)
        return self._mark

    def new_since_mark(self) -> list:
        return self._records[self._mark:]

    def assert_no_new_compiles(self, what: str = "after warmup") -> None:
        new = self.new_since_mark()
        if new:
            detail = ", ".join(
                f"{r.name}" + (f" ({r.treedef})" if r.treedef else "")
                for r in new[:8])
            raise AssertionError(
                f"{len(new)} new compile(s) {what}: {detail}"
                + ("..." if len(new) > 8 else ""))

    # ----------------------------------------------------- per-fn tracking ---
    def wrap(self, name: str, fn):
        """Instrument a jitted callable: after every call, cache growth is
        recorded with the call's arg treedef — the record that answers
        *which* function retraced and on what structure."""
        import jax
        state = [jit_cache_size(fn)]
        self._wrapped[name] = (fn, state)

        def wrapped(*args, **kwargs):
            out = fn(*args, **kwargs)
            n = jit_cache_size(fn)
            if n > state[0]:
                state[0] = n
                self._records.append(CompileRecord(
                    kind="cache", name=name, t_ns=time.perf_counter_ns(),
                    treedef=str(jax.tree_util.tree_structure((args, kwargs)))))
            return out

        return wrapped

    def cache_sizes(self) -> dict:
        """Current per-wrapped-fn compiled-program counts."""
        return {name: jit_cache_size(fn)
                for name, (fn, _) in self._wrapped.items()}
