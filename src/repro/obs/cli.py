"""Shared ``--trace`` / ``--metrics`` wiring for the launch drivers.

Every entry point (``repro.launch.train``, ``repro.launch.serve``,
``examples/sim_stragglers.py``) grows the same two flags through
`add_args` and wraps its run in `session`:

    obs_cli.add_args(ap)
    args = ap.parse_args(argv)
    with obs_cli.session(args):
        ...  # the run — instrumented libraries publish automatically

With neither flag passed the session installs nothing, so the run takes
the zero-overhead disabled path.  With ``--trace out.jsonl`` a `Tracer`
(provenance-stamped header) is installed for the duration; with
``--metrics out.json`` a `MetricsRegistry` is installed and its snapshot
(plus the same provenance stamp) is written on exit.  Convert a trace for
the Perfetto UI with ``python -m repro.obs.perfetto out.jsonl out.json``.
"""
from __future__ import annotations

from typing import Optional


def add_args(ap) -> None:
    """Install the observability flags on an argparse parser."""
    ap.add_argument("--trace", default=None, metavar="OUT.jsonl",
                    help="write a structured JSONL span trace here "
                         "(convert with python -m repro.obs.perfetto)")
    ap.add_argument("--metrics", default=None, metavar="OUT.json",
                    help="write a metrics snapshot (counters/gauges/"
                         "histograms + provenance) here on exit")


class session:
    """Context manager: install tracer/registry per ``args``, tear down
    and write outputs on exit (exception-safe — a crashed run still gets
    its partial trace flushed)."""

    def __init__(self, args):
        self.trace_path: Optional[str] = getattr(args, "trace", None)
        self.metrics_path: Optional[str] = getattr(args, "metrics", None)
        self._tracer = None
        self._registry = None
        self._prev_tracer = None
        self._prev_registry = None
        self._provenance = None

    def __enter__(self) -> "session":
        from . import trace as obs
        if self.trace_path or self.metrics_path:
            from .provenance import RunProvenance
            self._provenance = RunProvenance.collect().asdict()
        if self.trace_path:
            self._tracer = obs.Tracer(self.trace_path,
                                      provenance=self._provenance)
            self._prev_tracer = obs.install(self._tracer)
            from .jit_watch import ensure_listener
            ensure_listener()
        if self.metrics_path:
            from .metrics import MetricsRegistry
            self._registry = MetricsRegistry()
            self._prev_registry = obs.install_registry(self._registry)
        return self

    def __exit__(self, *exc):
        from . import trace as obs
        if self._registry is not None:
            obs.install_registry(self._prev_registry)
            self._registry.to_json(self.metrics_path,
                                   provenance=self._provenance)
            print(f"metrics snapshot: {self.metrics_path}")
        if self._tracer is not None:
            obs.install(self._prev_tracer)
            self._tracer.close()
            print(f"trace: {self.trace_path} "
                  f"({self._tracer.n_records} records; view: python -m "
                  f"repro.obs.perfetto {self.trace_path} out.json)")
        return False
