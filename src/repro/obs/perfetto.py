"""Export `repro.obs` JSONL traces to Chrome/Perfetto ``trace_event`` JSON,
and validate them against the trace schema.

The exporter maps each span to a complete ("X") event and each instant to
an instant ("i") event; Perfetto nests same-tid "X" events by time
containment, which is exactly how the tracer's context-manager spans
relate.  Span categories become ``cat`` (Perfetto lets you filter on
them) and process/thread metadata names the pid so the timeline reads
"repro <pid>" instead of a bare number.  Open the output at
``https://ui.perfetto.dev`` (or ``chrome://tracing``).

CLI — convert, validate, and optionally assert layer coverage::

  PYTHONPATH=src python -m repro.obs.perfetto run.jsonl run.perfetto.json \
      --require-layers engine,sim,wire

``--validate-only`` skips the conversion (CI uses it to check a trace
without keeping the converted artifact).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

_SPAN_KEYS = {"name", "cat", "ts_us", "dur_us", "pid", "tid"}
_INSTANT_KEYS = {"name", "cat", "ts_us", "pid", "tid"}


def read_trace(path: str) -> tuple:
    """(meta, records) from a JSONL trace; raises ValueError on malformed
    lines so a truncated/corrupt trace fails loudly."""
    meta, records = None, []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: not JSON: {e}") from None
            if rec.get("type") == "meta":
                if meta is not None:
                    raise ValueError(f"{path}:{i + 1}: duplicate meta record")
                meta = rec
            else:
                records.append((i + 1, rec))
    return meta, records


def validate(path: str, require_layers: Optional[set] = None) -> dict:
    """Validate a JSONL trace against the schema: exactly one meta header
    carrying a provenance stamp, and every record a well-formed span or
    instant (required keys present, timestamps/durations numeric and
    non-negative).  Returns a summary dict (record counts, layers seen,
    provenance); raises ValueError naming the first offending line."""
    meta, records = read_trace(path)
    if meta is None:
        raise ValueError(f"{path}: no meta header record")
    prov = meta.get("provenance")
    if not isinstance(prov, dict) or "jax_version" not in prov:
        raise ValueError(f"{path}: meta record lacks a provenance stamp")
    layers, n_spans, n_instants = set(), 0, 0
    for lineno, rec in records:
        kind = rec.get("type")
        if kind == "span":
            need, n_spans = _SPAN_KEYS, n_spans + 1
        elif kind == "instant":
            need, n_instants = _INSTANT_KEYS, n_instants + 1
        else:
            raise ValueError(f"{path}:{lineno}: unknown record type {kind!r}")
        missing = need - rec.keys()
        if missing:
            raise ValueError(f"{path}:{lineno}: {kind} record missing "
                             f"{sorted(missing)}")
        for k in ("ts_us", "dur_us"):
            if k in need and (not isinstance(rec[k], (int, float))
                              or rec[k] < 0):
                raise ValueError(f"{path}:{lineno}: bad {k}: {rec[k]!r}")
        layers.add(rec["cat"])
    if require_layers:
        missing = set(require_layers) - layers
        if missing:
            raise ValueError(
                f"{path}: trace has spans from layers {sorted(layers)} but "
                f"is missing required layers {sorted(missing)}")
    return {"path": path, "spans": n_spans, "instants": n_instants,
            "layers": sorted(layers), "provenance": prov}


def to_perfetto(in_path: str, out_path: str) -> int:
    """Convert a JSONL trace to ``trace_event`` JSON; returns the number of
    events written.  The input is validated as a side effect (conversion
    reuses the same reader)."""
    meta, records = read_trace(in_path)
    events, pids = [], set()
    for _, rec in records:
        ev = {"name": rec["name"], "cat": rec.get("cat", "app"),
              "pid": rec["pid"], "tid": rec["tid"], "ts": rec["ts_us"]}
        if rec["type"] == "span":
            ev.update(ph="X", dur=rec["dur_us"])
        else:
            ev.update(ph="i", s="t")
        if rec.get("args"):
            ev["args"] = rec["args"]
        events.append(ev)
        pids.add(rec["pid"])
    for pid in sorted(pids):
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": f"repro {pid}"}})
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    if meta is not None:
        doc["otherData"] = {"provenance": meta.get("provenance"),
                            "wall_iso": meta.get("wall_iso")}
    with open(out_path, "w") as f:
        json.dump(doc, f)
    return len(events)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("trace", help="JSONL trace written by repro.obs.Tracer")
    ap.add_argument("out", nargs="?", default=None,
                    help="output trace_event JSON (default: "
                         "<trace>.perfetto.json)")
    ap.add_argument("--require-layers", default=None,
                    help="comma-separated span categories that must appear "
                         "(e.g. engine,sim,wire) — exit 1 if any is missing")
    ap.add_argument("--validate-only", action="store_true",
                    help="validate the JSONL against the trace schema "
                         "without writing the converted file")
    args = ap.parse_args(argv)

    layers = (set(args.require_layers.split(","))
              if args.require_layers else None)
    summary = validate(args.trace, require_layers=layers)
    print(f"{args.trace}: {summary['spans']} spans, "
          f"{summary['instants']} instants, layers={summary['layers']}, "
          f"git={summary['provenance'].get('git_sha', '?')}")
    if not args.validate_only:
        out = args.out or args.trace + ".perfetto.json"
        n = to_perfetto(args.trace, out)
        print(f"wrote {out}: {n} trace events (open at ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
