"""Metrics: counters, gauges, fixed-bucket histograms, and the registry.

Everything is plain host Python over floats — publishing a sample is a
dict lookup plus arithmetic, cheap enough for per-chunk (train) and
per-step (serve) cadences, and nothing here can reach into a jitted
program.  `MetricsRegistry.snapshot()` returns a JSON-ready dict;
``to_json`` stamps it with `RunProvenance` so a snapshot is interpretable
off the machine that produced it.

Percentiles come in two forms, one implementation each:

* ``percentile``/``percentiles`` — exact, over a materialized sequence.
  This is *the* percentile implementation the serving benchmarks report
  p50/p90/p99 through (`serve.loadgen.summarize`), replacing the ad-hoc
  math that used to live in the bench script.
* `Histogram.percentile` — streaming estimate from fixed log-spaced
  buckets (linear interpolation inside the bucket, exact min/max
  clamping).  Bucket invariants and estimate bounds are hypothesis-pinned
  in ``tests/test_obs.py``.
"""
from __future__ import annotations

import json
import math
from typing import Optional, Sequence

import numpy as np


# ------------------------------------------------------------- percentiles ---
def percentile(xs: Sequence[float], q: float, empty: Optional[float] = -1.0
               ) -> float:
    """Exact q-th percentile (linear interpolation); ``empty`` on empty
    input.  The serving reports pass ``empty=None`` so an empty series
    serializes as JSON null instead of a fake -1.0 latency."""
    if not len(xs):
        return empty
    return float(np.percentile(np.asarray(xs, np.float64), q))


def percentiles(xs: Sequence[float], qs: Sequence[float] = (50, 90, 99),
                empty: Optional[float] = -1.0) -> dict:
    return {f"p{q:g}": percentile(xs, q, empty=empty) for q in qs}


# ------------------------------------------------------------- instruments ---
class Counter:
    """Monotonically increasing count (events, bytes, drops)."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-set value (queue depth, resident bytes, version)."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = None

    def set(self, v) -> None:
        self.value = v

    def snapshot(self):
        return self.value


def default_buckets(lo: float = 1e-6, hi: float = 1e6,
                    per_decade: int = 4) -> tuple:
    """Log-spaced bucket upper bounds covering [lo, hi] — wide enough for
    seconds-scale latencies and byte counts alike at ~19% resolution."""
    n = int(round(math.log10(hi / lo) * per_decade))
    return tuple(lo * (hi / lo) ** (i / n) for i in range(n + 1))


class Histogram:
    """Fixed-bucket histogram with streaming percentile estimates.

    ``bounds`` are ascending bucket *upper* edges; a sample lands in the
    first bucket whose bound is >= the sample, or the overflow bucket.
    Estimates interpolate linearly inside the winning bucket and clamp to
    the exact observed min/max, so for any data: ``count`` is exact,
    ``percentile`` is monotone in q, and every estimate lies in
    [min, max] (hypothesis-pinned)."""
    __slots__ = ("bounds", "counts", "overflow", "count", "total",
                 "vmin", "vmax")

    def __init__(self, bounds: Optional[Sequence[float]] = None):
        self.bounds = tuple(sorted(bounds)) if bounds else default_buckets()
        if len(self.bounds) < 1:
            raise ValueError("need at least one bucket bound")
        self.counts = [0] * len(self.bounds)
        self.overflow = 0
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.total += x
        if x < self.vmin:
            self.vmin = x
        if x > self.vmax:
            self.vmax = x
        # bisect over a ~50-entry tuple: O(log n), no numpy round trip
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if self.bounds[mid] < x:
                lo = mid + 1
            else:
                hi = mid
        if lo == len(self.bounds):
            self.overflow += 1
        else:
            self.counts[lo] += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Estimated q-th percentile (q in [0, 100]); -1.0 when empty."""
        if self.count == 0:
            return -1.0
        rank = q / 100.0 * self.count
        seen = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else min(self.vmin, 0.0)
                hi = self.bounds[i]
                frac = (rank - seen) / c
                est = lo + (hi - lo) * frac
                return float(min(max(est, self.vmin), self.vmax))
            seen += c
        return float(self.vmax)       # rank fell in the overflow bucket

    def snapshot(self) -> dict:
        out = {"count": self.count, "mean": self.mean,
               "min": self.vmin if self.count else None,
               "max": self.vmax if self.count else None,
               **{f"p{q}": self.percentile(q) for q in (50, 90, 99)}}
        # only the occupied buckets: snapshots stay readable for sparse data
        out["buckets"] = {f"le_{self.bounds[i]:g}": c
                         for i, c in enumerate(self.counts) if c}
        if self.overflow:
            out["buckets"][f"gt_{self.bounds[-1]:g}"] = self.overflow
        return out


# ---------------------------------------------------------------- registry ---
class MetricsRegistry:
    """Name -> instrument, get-or-create.  One registry per run; install
    it globally with ``obs.install_registry`` so library code can publish
    without threading a handle through every constructor."""

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(*args)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"requested {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        return self._get(name, Histogram, bounds)

    def __len__(self) -> int:
        return len(self._metrics)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def snapshot(self) -> dict:
        return {name: m.snapshot()
                for name, m in sorted(self._metrics.items())}

    def to_json(self, path: str, provenance: Optional[dict] = None) -> dict:
        """Write ``{"provenance": ..., "metrics": snapshot()}`` to ``path``
        and return it."""
        if provenance is None:
            from .provenance import RunProvenance
            provenance = RunProvenance.collect().asdict()
        doc = {"provenance": provenance, "metrics": self.snapshot()}
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, default=float)
        return doc
