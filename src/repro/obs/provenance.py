"""`RunProvenance` — the who/where/how stamp on every measurement.

The ROADMAP's measurement-discipline lesson (the 4012µs-vs-323µs
interpret-vs-compiled comparison that turned out to be meaningless) is
that a number without its environment is noise.  `collect()` gathers the
facts that change what a number means — git sha (and whether the tree was
dirty), jax/jaxlib versions, backend/platform, device count, x64 mode,
and whether the Pallas kernels run interpreted — and every trace header,
metrics snapshot, and ``BENCH_*.json`` carries the result.

Collection is defensive: a missing git binary, a non-repo checkout, or an
import failure degrades the field to None instead of failing the run the
stamp was meant to describe.
"""
from __future__ import annotations

import dataclasses
import os
import platform as _platform
import subprocess
import sys
from dataclasses import dataclass
from typing import Optional


def _git(args: list, cwd: str) -> Optional[str]:
    try:
        out = subprocess.run(["git", *args], cwd=cwd, capture_output=True,
                             text=True, timeout=10)
        return out.stdout.strip() if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


@dataclass(frozen=True)
class RunProvenance:
    git_sha: Optional[str] = None
    git_dirty: Optional[bool] = None
    jax_version: Optional[str] = None
    jaxlib_version: Optional[str] = None
    backend: Optional[str] = None
    n_devices: Optional[int] = None
    platform: Optional[str] = None
    python: Optional[str] = None
    x64: Optional[bool] = None
    kernel_interpret: Optional[bool] = None
    platform_preset: Optional[str] = None
    xla_flags: Optional[str] = None
    argv: Optional[str] = None

    @classmethod
    def collect(cls) -> "RunProvenance":
        repo = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))   # src/repro/obs/..
        sha = _git(["rev-parse", "HEAD"], repo)
        dirty = None
        if sha is not None:
            status = _git(["status", "--porcelain"], repo)
            dirty = bool(status) if status is not None else None
        jax_version = jaxlib_version = backend = None
        n_devices = x64 = None
        try:
            import jax
            import jaxlib
            jax_version = jax.__version__
            jaxlib_version = jaxlib.__version__
            # default_backend initializes the backend; by stamp time every
            # caller has long since paid that cost
            backend = jax.default_backend()
            n_devices = jax.device_count()
            x64 = bool(jax.config.read("jax_enable_x64"))
        except Exception:  # pragma: no cover - jax always importable here
            pass
        interpret = None
        try:
            from ..kernels.era_sharpen import resolve_interpret
            interpret = bool(resolve_interpret(None))
        except Exception:  # pragma: no cover - kernels unavailable
            pass
        preset = None
        try:
            from ..launch.platform import active
            p = active()
            preset = p.name if p is not None else None
        except Exception:  # pragma: no cover - launch plane unavailable
            pass
        return cls(git_sha=sha, git_dirty=dirty, jax_version=jax_version,
                   jaxlib_version=jaxlib_version, backend=backend,
                   n_devices=n_devices,
                   platform=_platform.platform(),
                   python=_platform.python_version(),
                   x64=x64, kernel_interpret=interpret,
                   platform_preset=preset,
                   xla_flags=os.environ.get("XLA_FLAGS"),
                   argv=" ".join(sys.argv))

    def asdict(self) -> dict:
        return dataclasses.asdict(self)
