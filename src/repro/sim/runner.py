"""`SimRunner` — event-driven federation simulation around any `FedEngine`.

The runner does not fork the training loop: each virtual round *is* a plain
``FedEngine.run(rounds=1)`` call, with the scheduler's `RoundPlan` injected
through the engine's ``on_ctx`` hook as ``BatchCtx.mask`` / ``.stale``.  The
jitted round math, RNG discipline, eval, history and checkpointing are the
engine's own — so with an idealized scheduler (full participation, no
deadline) the hook leaves the ctx untouched and every round is bit-for-bit
identical to the un-simulated engine (asserted by tests/test_sim.py).

Around the rounds, the runner keeps the books the engine cannot: the virtual
clock (charged from *measured* per-leg codec bytes), the cumulative byte
ledger, and a `SimHistory` of accuracy against wallclock — the paper's
Figs. 5-8 axes.  ``save_state``/``load_state`` checkpoint the engine state
plus a JSON sidecar holding the scheduler state (virtual clock included) and
the sim history, so a resumed simulation continues the same time axis.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.algorithms import ClientState, EMPTY, RoundState
from ..core.cohort import ClientStore, build_slab, slab_ctx_plan
from ..core.engine import FedEngine
from ..obs import trace as obs
from .history import SimHistory
from .scheduler import RoundPlan


def _publish_chunk(runner, plans, up_bytes: float, down_bytes: float) -> None:
    """Per-chunk metrics both runners share: the wire-byte ledger and the
    participation the schedule actually delivered."""
    reg = obs.current_registry()
    if reg is None:
        return
    n_part = sum(p.n_participants for p in plans)
    reg.counter("sim.up_bytes").inc(int(up_bytes) * n_part)
    reg.counter("sim.down_bytes").inc(int(down_bytes) * len(plans))
    reg.counter("sim.participant_rounds").inc(n_part)
    reg.gauge("sim.cum_bytes").set(runner.cum_bytes)


@dataclass
class SimRunner:
    """Drive ``engine`` under ``scheduler``'s participation/timing model.

    ``seed`` feeds a per-round ``np.random.default_rng([seed, round])`` so
    participation draws are reproducible and checkpoint/resume replays the
    identical fleet behaviour without serializing generator state."""
    engine: FedEngine
    scheduler: Any                      # SyncScheduler | AsyncBufferScheduler
    seed: int = 0
    history: SimHistory = field(default_factory=SimHistory)
    cum_bytes: int = 0
    _leg_bytes: Optional[tuple] = None  # cached (up, down) measured bytes

    def _hook(self, plan: RoundPlan, budget=None):
        if self.scheduler.idealized:
            return None                  # ctx untouched -> bit-exact engine
        mask = jnp.asarray(plan.mask, jnp.float32)
        stale = jnp.asarray(plan.staleness, jnp.int32)

        def on_ctx(r, ctx):
            return dataclasses.replace(ctx, mask=mask, stale=stale,
                                       active_budget=budget)

        return on_ctx

    def _budget(self, active_budget, plans) -> Optional[int]:
        """Resolve the participation-sparse budget for one engine call.
        ``"auto"`` takes the scheduler's static bound; an int is trusted
        (validated against the materialized plans); None keeps the dense
        masked path.  A budget >= K buys nothing, so it degrades to None."""
        if active_budget == "auto":
            active_budget = getattr(self.scheduler, "active_budget", None)
        if active_budget is None:
            return None
        K = self.scheduler.population.n_clients
        if active_budget >= K:
            # buys nothing over the dense path — degrade before enforcing
            # the sparse contract, which only the sparse plane needs
            return None
        need = max(int(p.mask.sum()) for p in plans)
        if need > active_budget:
            raise ValueError(
                f"active_budget {active_budget} < {need} scheduled "
                f"participants — the sparse round would silently skip "
                f"clients that carry aggregation weight")
        if min(int(p.mask.sum()) for p in plans) < 1:
            raise ValueError(
                "sparse rounds need >= 1 participant per round (an empty "
                "round's aggregation falls back to uniform-over-K, which "
                "needs the uploads the sparse plane never computes); pass "
                "active_budget=None for this schedule")
        return int(active_budget)

    # --------------------------------------------------------------- run ----
    def run(self, state: RoundState, data, rounds: Optional[int] = None,
            weights=EMPTY, log_every: int = 1,
            chunk_rounds: int = 1, active_budget="auto") -> RoundState:
        """Drive ``rounds`` virtual rounds.  ``chunk_rounds=k`` runs the
        fused sim path when the scheduler allows it: sync participation is
        computable a priori from the measured per-leg bytes and the client
        profiles, so k `RoundPlan`s are drawn up front, stacked into a
        (k, K) mask/stale plan, and fed through the engine's compiled
        ``lax.scan`` as per-step ctx inputs — bitwise identical to the
        per-round path (tests/test_engine_scan.py).  Async scheduling
        (``plannable=False``) keeps the per-round path.

        ``active_budget`` drives the participation-sparse round plane:
        ``"auto"`` (default) takes the scheduler's static participant bound
        (ceil(fraction*K) for sync rounds, the buffer size M for async), so
        a 10%-participation fleet computes ~10% of the client stack per
        round — bitwise identical to the dense masked round.  Pass an int
        to override or ``None`` to force the dense path."""
        eng = self.engine
        rounds = eng.algo.hp.rounds if rounds is None else rounds
        # per-leg bytes measured once on the encoded payload (shapes are
        # round-invariant, so the eval_shape traces are cached across
        # ``run`` calls too); the clock charges these, not analytic numbers
        if self._leg_bytes is None:
            self._leg_bytes = eng.measured_leg_bytes(state, data)
        up_bytes, down_bytes = self._leg_bytes
        fused = (chunk_rounds > 1
                 and getattr(self.scheduler, "plannable", False))
        prev_hook = eng.on_ctx
        try:
            done = 0
            while done < rounds:
                k = min(chunk_rounds, rounds - done) if fused else 1
                r0 = eng.rounds_done
                with obs.span("sim.plan", "sim", rounds=k, start_round=r0):
                    plans = [self.scheduler.next_round(
                        np.random.default_rng([self.seed, r0 + i]),
                        up_bytes, down_bytes) for i in range(k)]
                n_hist = len(eng.history)
                budget = (None if self.scheduler.idealized
                          else self._budget(active_budget, plans))
                if fused:
                    eng.on_ctx = None
                    ctx_plan = None
                    if not self.scheduler.idealized:
                        ctx_plan = {
                            "mask": jnp.asarray(
                                np.stack([p.mask for p in plans]),
                                jnp.float32),
                            "stale": jnp.asarray(
                                np.stack([p.staleness for p in plans]),
                                jnp.int32)}
                    state = eng.run(state, data, rounds=k, weights=weights,
                                    log_every=log_every, chunk_rounds=k,
                                    ctx_plan=ctx_plan, active_budget=budget)
                else:
                    eng.on_ctx = self._hook(plans[0], budget)
                    state = eng.run(state, data, rounds=1, weights=weights,
                                    log_every=log_every)
                eng_recs = {rec["round"]: rec
                            for rec in eng.history[n_hist:]}
                for i, plan in enumerate(plans):
                    self.cum_bytes += (up_bytes * plan.n_participants
                                       + down_bytes)
                    rec = {"round": r0 + i + 1,
                           "t_round": plan.duration, "t_cum": plan.t_end,
                           "participants": plan.n_participants,
                           "dropped": int(plan.dropped.sum()),
                           "mean_staleness": float(
                               plan.staleness[plan.mask].mean()
                               if plan.mask.any() else 0.0),
                           "up_bytes": up_bytes * plan.n_participants,
                           "down_bytes": down_bytes,
                           "cum_bytes": self.cum_bytes}
                    eng_rec = eng_recs.get(r0 + i + 1)
                    if eng_rec is not None:    # engine logged this round
                        rec.update({k2: v for k2, v in eng_rec.items()
                                    if k2 not in rec})
                    self.history.append(rec)
                _publish_chunk(self, plans, up_bytes, down_bytes)
                done += k
        finally:
            eng.on_ctx = prev_hook
        return state

    # ------------------------------------------------------- checkpointing --
    def _sidecar(self, path: str) -> str:
        return path + ".sim.json"

    def save_state(self, path: str, state: RoundState) -> None:
        """Engine checkpoint + JSON sidecar: scheduler state (virtual clock,
        pending/arrival books), sim history, byte ledger."""
        self.engine.save_state(path, state)
        with open(self._sidecar(path), "w") as f:
            json.dump({"scheduler": self.scheduler.state(),
                       "history": self.history.records,
                       "cum_bytes": self.cum_bytes,
                       "seed": self.seed}, f, default=float)

    def load_state(self, path: str, like: RoundState,
                   shardings=None) -> RoundState:
        state = self.engine.load_state(path, like, shardings=shardings)
        sidecar = self._sidecar(path)
        if os.path.exists(sidecar):
            with open(sidecar) as f:
                raw = json.load(f)
            self.scheduler.set_state(raw["scheduler"])
            self.history = SimHistory(records=raw["history"])
            self.cum_bytes = int(raw["cum_bytes"])
        return state


@dataclass
class CohortRunner:
    """Cohort-resident federation: `SimRunner`'s million-client form.

    Nothing in the hot path is O(K): the scheduler plans `CohortPlan`s (id
    arrays, O(m log K) draws), client state lives host-side in a
    `core.cohort.ClientStore` keyed by global id (lazily initialized, so
    untouched clients cost nothing), per-chunk data comes from a provider's
    ``slab(ids)``, and the engine runs its ordinary fused rounds over an
    (S,)-lane slab with ``BatchCtx.cohort`` carrying the id→lane mapping.
    At small K this is **bitwise identical** to `SimRunner`'s dense masked
    rounds fed the same plans (tests/test_cohort.py) — the house invariant
    that pins the refactor layer by layer.

    ``state`` passed to ``run`` holds only the server side (e.g.
    ``algo.init_server``); slabs stream through it per chunk.  ``store`` is
    None for algorithms with ephemeral client state (FedAvg)."""
    engine: FedEngine
    scheduler: Any
    provider: Any                       # ArrayProvider | SyntheticProvider
    store: Optional[ClientStore] = None
    seed: int = 0
    history: SimHistory = field(default_factory=SimHistory)
    cum_bytes: int = 0
    peak_slab_bytes: int = 0
    _leg_bytes: Optional[tuple] = None

    def resident_bytes(self) -> int:
        """Host bytes of all stored client state — the resident-memory
        number the population-scaling benchmark tracks (flat in K)."""
        return 0 if self.store is None else self.store.resident_bytes()

    def _probe_state(self, state: RoundState) -> RoundState:
        """A 1-lane slab state for byte measurement (`measured_leg_bytes`
        only eval_shapes the payload, but needs a client lane to exist)."""
        if self.store is None:
            return state
        return dataclasses.replace(state,
                                   clients=self.store.gather(np.zeros(1)))

    def run(self, state: RoundState, rounds: Optional[int] = None,
            weights=EMPTY, log_every: int = 1,
            chunk_rounds: int = 1) -> RoundState:
        """Drive ``rounds`` virtual rounds, ``chunk_rounds`` at a time: each
        chunk's cohorts are planned up front, their sorted union becomes one
        fixed-size slab (static S = chunk_rounds * scheduler.active_budget,
        so the engine's jit caches stay warm across chunks), and the whole
        chunk runs as one fused scan with the (k, S) mask/stale plan.
        Participation sparsity inside the slab reuses the engine's
        ``active_budget`` plane when the per-round bound is below S."""
        eng = self.engine
        sched = self.scheduler
        rounds = eng.algo.hp.rounds if rounds is None else rounds
        K = sched.population.n_clients
        budget = int(getattr(sched, "active_budget", K))
        if self._leg_bytes is None:
            self._leg_bytes = eng.measured_leg_bytes(
                self._probe_state(state), self.provider.slab(np.zeros(1)))
        up_bytes, down_bytes = self._leg_bytes
        done = 0
        while done < rounds:
            k = min(chunk_rounds, rounds - done)
            r0 = eng.rounds_done
            with obs.span("sim.plan", "sim", rounds=k, start_round=r0):
                plans = [sched.next_cohort(
                    np.random.default_rng([self.seed, r0 + i]),
                    up_bytes, down_bytes) for i in range(k)]
                S = min(K, k * budget)
                slab_ids, n_real = build_slab([p.ids for p in plans], S)
                plan_np = slab_ctx_plan(plans, slab_ids, n_real)
            with obs.span("cohort.gather", "cohort", slab=S, real=n_real):
                clients = (self.store.gather(slab_ids)
                           if self.store is not None else state.clients)
            sstate = dataclasses.replace(state, clients=clients)
            self.peak_slab_bytes = max(self.peak_slab_bytes, sum(
                np.asarray(l).nbytes
                for l in jax.tree_util.tree_leaves(clients)))
            n_hist = len(eng.history)
            sstate = eng.run(
                sstate, self.provider.slab(slab_ids), rounds=k,
                weights=weights, log_every=log_every, chunk_rounds=k,
                ctx_plan={"mask": jnp.asarray(plan_np["mask"]),
                          "stale": jnp.asarray(plan_np["stale"])},
                active_budget=(budget if budget < S else None),
                cohort=jnp.asarray(slab_ids), population=K)
            if self.store is not None:
                with obs.span("cohort.scatter", "cohort", real=n_real):
                    self.store.scatter(slab_ids, sstate.clients, n_real)
            state = dataclasses.replace(sstate, clients=state.clients)
            eng_recs = {rec["round"]: rec for rec in eng.history[n_hist:]}
            for i, plan in enumerate(plans):
                self.cum_bytes += (up_bytes * plan.n_participants
                                   + down_bytes)
                rec = {"round": r0 + i + 1,
                       "t_round": plan.duration, "t_cum": plan.t_end,
                       "participants": plan.n_participants,
                       "dropped": int(plan.dropped_ids.size),
                       "mean_staleness": float(
                           plan.staleness.mean() if plan.ids.size else 0.0),
                       "up_bytes": up_bytes * plan.n_participants,
                       "down_bytes": down_bytes,
                       "cum_bytes": self.cum_bytes,
                       "resident_bytes": self.resident_bytes()}
                eng_rec = eng_recs.get(r0 + i + 1)
                if eng_rec is not None:
                    rec.update({k2: v for k2, v in eng_rec.items()
                                if k2 not in rec})
                self.history.append(rec)
            _publish_chunk(self, plans, up_bytes, down_bytes)
            reg = obs.current_registry()
            if reg is not None:
                reg.gauge("cohort.resident_bytes").set(self.resident_bytes())
                reg.gauge("cohort.peak_slab_bytes").set(self.peak_slab_bytes)
                reg.histogram("cohort.slab_real").observe(float(n_real))
            done += k
        return state

    # ------------------------------------------------------- checkpointing --
    def _sidecar(self, path: str) -> str:
        return path + ".sim.json"

    def _store_path(self, path: str) -> str:
        return path + ".store"

    def save_state(self, path: str, state: RoundState) -> None:
        """Three-part checkpoint: engine state (the server side + round
        counter/history), the host-side client store, and the sim sidecar
        (scheduler books incl. virtual clock, sim history, byte ledger)."""
        self.engine.save_state(path, state)
        if self.store is not None:
            self.store.save(self._store_path(path))
        with open(self._sidecar(path), "w") as f:
            json.dump({"scheduler": self.scheduler.state(),
                       "history": self.history.records,
                       "cum_bytes": self.cum_bytes,
                       "seed": self.seed}, f, default=float)

    def load_state(self, path: str, like: RoundState,
                   shardings=None) -> RoundState:
        state = self.engine.load_state(path, like, shardings=shardings)
        if self.store is not None and os.path.exists(self._store_path(path)):
            self.store.load(self._store_path(path))
        sidecar = self._sidecar(path)
        if os.path.exists(sidecar):
            with open(sidecar) as f:
                raw = json.load(f)
            self.scheduler.set_state(raw["scheduler"])
            self.history = SimHistory(records=raw["history"])
            self.cum_bytes = int(raw["cum_bytes"])
        return state
