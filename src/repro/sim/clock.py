"""Virtual event clock: wallclock accounting for one federated round.

The clock charges each selected client the full leg sequence — broadcast
download, local compute, payload upload — using the *measured* codec bytes
from `repro.core.wire` (via ``FedEngine.measured_leg_bytes``), never analytic
estimates.  A straggler deadline either drops late clients from the round
(``"drop"``) or admits their upload into the next aggregation (``"admit"``,
where it arrives stale).  All per-client math is vectorized NumPy.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class RoundTiming:
    """What one round cost in virtual time."""
    duration: float            # seconds this round occupied on the wallclock
    latency: np.ndarray        # (K,) per-client full-leg latency (selected
    #                            clients; unselected entries hold +inf)
    on_time: np.ndarray        # (K,) bool — selected and inside the deadline
    dropped: np.ndarray        # (K,) bool — selected but past the deadline


@dataclass(frozen=True)
class CohortTiming:
    """`RoundTiming`'s O(m) form: arrays align with a cohort's (m,) ids
    instead of the (K,) population."""
    duration: float
    latency: np.ndarray        # (m,) per-member full-leg latency
    on_time: np.ndarray        # (m,) bool
    dropped: np.ndarray        # (m,) bool


@dataclass
class VirtualClock:
    """Monotone virtual time.  ``now`` is checkpointed by `SimRunner` so a
    resumed simulation continues the same wallclock axis."""
    now: float = 0.0

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"time must not run backwards (dt={dt})")
        self.now += float(dt)
        return self.now

    def charge_sync_round(self, selected: np.ndarray, latency: np.ndarray,
                          deadline: float | None = None) -> RoundTiming:
        """Synchronous (FedAvg-style) round: the server waits for every
        selected client, or until ``deadline`` seconds — whichever is first.
        Clients past the deadline are marked dropped; if *everyone* misses
        it, the single fastest selected client is kept (an empty round would
        silently degenerate to the uniform-fallback aggregate).  Advances
        ``now`` by the round duration."""
        lat = np.where(selected, latency, np.inf)
        if deadline is None:
            on_time = selected.copy()
        else:
            on_time = selected & (lat <= deadline)
            if selected.any() and not on_time.any():
                fastest = int(np.argmin(lat))
                on_time = np.zeros_like(selected)
                on_time[fastest] = True
        dropped = selected & ~on_time
        if not selected.any():
            duration = 0.0
        elif dropped.any():
            # the round closed at the deadline (or at the forced-kept
            # fastest client, whichever came later)
            duration = float(max(deadline, np.min(lat[on_time])))
        else:
            duration = float(np.max(lat[on_time]))
        self.advance(duration)
        return RoundTiming(duration, lat, on_time, dropped)

    def charge_cohort(self, latency: np.ndarray,
                      deadline: float | None = None) -> CohortTiming:
        """`charge_sync_round` over a cohort's (m,) latencies — identical
        deadline/forced-keep/duration semantics, but every array is cohort-
        sized: the million-client path charges m members, never K lanes."""
        lat = np.asarray(latency, np.float64)
        m = lat.shape[0]
        if deadline is None:
            on_time = np.ones(m, bool)
        else:
            on_time = lat <= deadline
            if m and not on_time.any():
                on_time = np.zeros(m, bool)
                on_time[int(np.argmin(lat))] = True
        dropped = ~on_time if m else np.zeros(0, bool)
        if m == 0:
            duration = 0.0
        elif dropped.any():
            duration = float(max(deadline, np.min(lat[on_time])))
        else:
            duration = float(np.max(lat[on_time]))
        self.advance(duration)
        return CohortTiming(duration, lat, on_time, dropped)
