"""Per-client device/link profiles and participation samplers.

The paper's efficiency claim is plotted against *cumulative upload time* on
heterogeneous mobile devices (Figs. 5-8), so a reproduction needs a model of
who shows up each round and how slow their link is.  A `ClientPopulation`
holds vectorized per-client profiles (compute seconds per round, uplink and
downlink bytes/s, availability); factories draw them from configurable
distributions — lognormal link rates are the standard mobile-network model.

Everything here is plain NumPy: the sim layer runs at Python level between
jitted rounds; only the resulting participation mask / staleness vector
crosses into jit (as `BatchCtx.mask` / ``.stale``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class ClientPopulation:
    """Vectorized per-client profiles; all arrays are shape (K,)."""
    compute_time: np.ndarray     # seconds of local work per round
    uplink: np.ndarray           # bytes/s client -> server
    downlink: np.ndarray         # bytes/s server -> client
    availability: np.ndarray     # P(client reachable in a round), in (0, 1]

    def __post_init__(self):
        for name in ("compute_time", "uplink", "downlink", "availability"):
            setattr(self, name, np.asarray(getattr(self, name), np.float64))

    @property
    def n_clients(self) -> int:
        return int(self.compute_time.shape[0])

    def latency(self, up_bytes: float, down_bytes: float) -> np.ndarray:
        """(K,) seconds for one round: receive the broadcast, compute, then
        upload — ``down/downlink + compute + up/uplink`` per client."""
        return (down_bytes / self.downlink + self.compute_time
                + up_bytes / self.uplink)

    def latency_ids(self, ids: np.ndarray, up_bytes: float,
                    down_bytes: float) -> np.ndarray:
        """`latency` restricted to the (m,) global ids of one cohort — the
        O(m) path the cohort schedulers charge, which never materializes a
        K-length latency workspace."""
        ids = np.asarray(ids, np.int64)
        return (down_bytes / self.downlink[ids] + self.compute_time[ids]
                + up_bytes / self.uplink[ids])

    def availability_cdf(self) -> np.ndarray:
        """Cumulative availability weights, built once (O(K)) and cached so
        every weighted draw is an O(log K) ``searchsorted`` instead of the
        O(K) normalization scan ``rng.choice(p=...)`` performs per call.
        The cache keys on the identity of the ``availability`` array:
        replacing the attribute invalidates it; in-place edits
        (``pop.availability[:] = ...``) require dropping ``_avail_cdf``."""
        cached = getattr(self, "_avail_cdf", None)
        if cached is None or cached[0] is not self.availability:
            self._avail_cdf = (self.availability,
                               np.cumsum(self.availability))
        return self._avail_cdf[1]

    # ----------------------------------------------------------- factories --
    @classmethod
    def uniform(cls, K: int, compute_time: float = 1.0,
                uplink: float = 1e6, downlink: float = 1e7,
                availability: float = 1.0) -> "ClientPopulation":
        """Homogeneous population — the idealized-engine equivalence case."""
        ones = np.ones(K)
        return cls(compute_time * ones, uplink * ones, downlink * ones,
                   availability * ones)

    @classmethod
    def lognormal(cls, seed: int, K: int, compute_median: float = 1.0,
                  compute_sigma: float = 0.5, uplink_median: float = 1e6,
                  uplink_sigma: float = 1.0, downlink_factor: float = 10.0,
                  availability: tuple[float, float] = (1.0, 1.0)
                  ) -> "ClientPopulation":
        """Heterogeneous mobile fleet: lognormal compute and link rates
        (medians in seconds and bytes/s), downlink a fixed multiple of the
        uplink (asymmetric consumer links), availability uniform in the
        given range."""
        rng = np.random.default_rng(seed)
        compute = compute_median * rng.lognormal(0.0, compute_sigma, K)
        up = uplink_median * rng.lognormal(0.0, uplink_sigma, K)
        avail = rng.uniform(availability[0], availability[1], K)
        return cls(compute, up, downlink_factor * up, avail)


# ------------------------------------------------- participation samplers ----
def _cohort_size(K: int, fraction: float) -> int:
    return min(K, max(1, int(round(fraction * K))))


def floyd_sample(rng: np.random.Generator, K: int, m: int) -> np.ndarray:
    """Floyd's algorithm: m distinct uniform draws from [0, K) in O(m) time
    and memory — no K-length permutation/workspace, so drawing 100 of 10^6
    clients costs the same as 100 of 10^3.  Returns sorted ids."""
    if m >= K:
        return np.arange(K, dtype=np.int64)
    chosen = set()
    for j in range(K - m, K):
        t = int(rng.integers(0, j + 1))
        chosen.add(j if t in chosen else t)
    return np.fromiter(sorted(chosen), np.int64, len(chosen))


def weighted_draw_ids(rng: np.random.Generator, pop: ClientPopulation,
                      n: int) -> np.ndarray:
    """n availability-weighted draws (with replacement) via the cached CDF:
    O(n log K) per call after the one-time O(K) ``availability_cdf`` build."""
    cdf = pop.availability_cdf()
    u = rng.random(n) * cdf[-1]
    return np.searchsorted(cdf, u, side="right").astype(np.int64)


def cohort_uniform(rng: np.random.Generator, pop: ClientPopulation,
                   fraction: float = 1.0) -> np.ndarray:
    """Uniform cohort draw returning sorted (m,) global ids — the O(m log K)
    counterpart of `sample_uniform` (same exact cohort size, no (K,) mask)."""
    K = pop.n_clients
    return floyd_sample(rng, K, _cohort_size(K, fraction))


def cohort_available(rng: np.random.Generator, pop: ClientPopulation,
                     fraction: float = 1.0) -> np.ndarray:
    """Availability-weighted cohort draw returning sorted (<= m,) global
    ids.  Two stages, mirroring `sample_available`'s model without its
    per-draw O(K) scans: candidates come from the cached-CDF weighted draw
    (who the server *tries*), and each candidate answers w.p. its
    availability (the reachability coin).  Distinctness by rejection, with
    a bounded attempt budget; if nobody answers, fall back to the single
    most-available client so a round is never empty."""
    K = pop.n_clients
    m = _cohort_size(K, fraction)
    picked: set[int] = set()
    attempts, budget = 0, max(16 * m, 64)
    while len(picked) < m and attempts < budget:
        n = min(budget - attempts, max(m - len(picked), 8))
        cand = weighted_draw_ids(rng, pop, n)
        accept = rng.random(n) < pop.availability[cand]
        picked.update(int(c) for c in cand[accept])
        attempts += n
    if not picked:
        picked = {int(np.argmax(pop.availability))}
    return np.fromiter(sorted(picked), np.int64, len(picked))[:m]


COHORT_SAMPLERS = {"uniform": cohort_uniform, "available": cohort_available}


def sample_uniform(rng: np.random.Generator, pop: ClientPopulation,
                   fraction: float = 1.0) -> np.ndarray:
    """Uniform-K sampling: exactly ``max(1, round(fraction * K))`` clients,
    chosen uniformly without replacement.  Returns a (K,) bool mask.

    All samplers share the ``(rng, pop, fraction) -> mask`` signature so
    `SAMPLERS` is a real registry (`SyncScheduler` dispatches by name)."""
    K = pop.n_clients
    k = max(1, int(round(fraction * K)))
    mask = np.zeros(K, bool)
    mask[rng.choice(K, size=min(k, K), replace=False)] = True
    return mask


def sample_available(rng: np.random.Generator, pop: ClientPopulation,
                     fraction: float = 1.0) -> np.ndarray:
    """Availability-weighted sampling: candidates are drawn proportional to
    availability and each answers with probability its availability; falls
    back to the single most-available client if nobody answers.  The draw
    itself is `cohort_available` — O(m log K) per call against the cached
    availability CDF, where the previous implementation re-ran two O(K)
    scans (a K-wide reachability coin flip plus ``rng.choice(p=...)``'s
    normalization) on *every* round.  Only the returned (K,) mask is still
    dense; cohort-resident callers take the id form directly."""
    mask = np.zeros(pop.n_clients, bool)
    mask[cohort_available(rng, pop, fraction)] = True
    return mask


SAMPLERS = {"uniform": sample_uniform, "available": sample_available}
