"""Per-client device/link profiles and participation samplers.

The paper's efficiency claim is plotted against *cumulative upload time* on
heterogeneous mobile devices (Figs. 5-8), so a reproduction needs a model of
who shows up each round and how slow their link is.  A `ClientPopulation`
holds vectorized per-client profiles (compute seconds per round, uplink and
downlink bytes/s, availability); factories draw them from configurable
distributions — lognormal link rates are the standard mobile-network model.

Everything here is plain NumPy: the sim layer runs at Python level between
jitted rounds; only the resulting participation mask / staleness vector
crosses into jit (as `BatchCtx.mask` / ``.stale``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class ClientPopulation:
    """Vectorized per-client profiles; all arrays are shape (K,)."""
    compute_time: np.ndarray     # seconds of local work per round
    uplink: np.ndarray           # bytes/s client -> server
    downlink: np.ndarray         # bytes/s server -> client
    availability: np.ndarray     # P(client reachable in a round), in (0, 1]

    def __post_init__(self):
        for name in ("compute_time", "uplink", "downlink", "availability"):
            setattr(self, name, np.asarray(getattr(self, name), np.float64))

    @property
    def n_clients(self) -> int:
        return int(self.compute_time.shape[0])

    def latency(self, up_bytes: float, down_bytes: float) -> np.ndarray:
        """(K,) seconds for one round: receive the broadcast, compute, then
        upload — ``down/downlink + compute + up/uplink`` per client."""
        return (down_bytes / self.downlink + self.compute_time
                + up_bytes / self.uplink)

    # ----------------------------------------------------------- factories --
    @classmethod
    def uniform(cls, K: int, compute_time: float = 1.0,
                uplink: float = 1e6, downlink: float = 1e7,
                availability: float = 1.0) -> "ClientPopulation":
        """Homogeneous population — the idealized-engine equivalence case."""
        ones = np.ones(K)
        return cls(compute_time * ones, uplink * ones, downlink * ones,
                   availability * ones)

    @classmethod
    def lognormal(cls, seed: int, K: int, compute_median: float = 1.0,
                  compute_sigma: float = 0.5, uplink_median: float = 1e6,
                  uplink_sigma: float = 1.0, downlink_factor: float = 10.0,
                  availability: tuple[float, float] = (1.0, 1.0)
                  ) -> "ClientPopulation":
        """Heterogeneous mobile fleet: lognormal compute and link rates
        (medians in seconds and bytes/s), downlink a fixed multiple of the
        uplink (asymmetric consumer links), availability uniform in the
        given range."""
        rng = np.random.default_rng(seed)
        compute = compute_median * rng.lognormal(0.0, compute_sigma, K)
        up = uplink_median * rng.lognormal(0.0, uplink_sigma, K)
        avail = rng.uniform(availability[0], availability[1], K)
        return cls(compute, up, downlink_factor * up, avail)


# ------------------------------------------------- participation samplers ----
def sample_uniform(rng: np.random.Generator, pop: ClientPopulation,
                   fraction: float = 1.0) -> np.ndarray:
    """Uniform-K sampling: exactly ``max(1, round(fraction * K))`` clients,
    chosen uniformly without replacement.  Returns a (K,) bool mask.

    All samplers share the ``(rng, pop, fraction) -> mask`` signature so
    `SAMPLERS` is a real registry (`SyncScheduler` dispatches by name)."""
    K = pop.n_clients
    k = max(1, int(round(fraction * K)))
    mask = np.zeros(K, bool)
    mask[rng.choice(K, size=min(k, K), replace=False)] = True
    return mask


def sample_available(rng: np.random.Generator, pop: ClientPopulation,
                     fraction: float = 1.0) -> np.ndarray:
    """Availability-weighted sampling: each client is reachable w.p. its
    availability; among the reachable, up to ``round(fraction * K)`` are
    selected with probability proportional to availability.  Falls back to
    the single most-available client if nobody is reachable."""
    K = pop.n_clients
    reachable = rng.random(K) < pop.availability
    if not reachable.any():
        reachable = np.zeros(K, bool)
        reachable[int(np.argmax(pop.availability))] = True
    k = max(1, int(round(fraction * K)))
    idx = np.flatnonzero(reachable)
    if len(idx) > k:
        p = pop.availability[idx] / pop.availability[idx].sum()
        idx = rng.choice(idx, size=k, replace=False, p=p)
    mask = np.zeros(K, bool)
    mask[idx] = True
    return mask


SAMPLERS = {"uniform": sample_uniform, "available": sample_available}
