"""`repro.sim` — event-driven federation simulation.

Wraps any `FedAlgorithm`/`FedEngine` pair (no forked training loop) with the
system effects the paper's time-axis figures need: partial participation,
heterogeneous link rates, straggler deadlines, buffered-async aggregation
with staleness-decayed weights, and a virtual clock charged from *measured*
wire bytes.  See `runner.SimRunner` for the entry point.
"""
from .clients import (ClientPopulation, SAMPLERS, sample_available,
                      sample_uniform)
from .clock import RoundTiming, VirtualClock
from .history import SimHistory
from .runner import SimRunner
from .scheduler import AsyncBufferScheduler, RoundPlan, SyncScheduler

__all__ = [
    "AsyncBufferScheduler", "ClientPopulation", "RoundPlan", "RoundTiming",
    "SAMPLERS", "SimHistory", "SimRunner", "SyncScheduler", "VirtualClock",
    "sample_available", "sample_uniform",
]
