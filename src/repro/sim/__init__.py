"""`repro.sim` — event-driven federation simulation.

Wraps any `FedAlgorithm`/`FedEngine` pair (no forked training loop) with the
system effects the paper's time-axis figures need: partial participation,
heterogeneous link rates, straggler deadlines, buffered-async aggregation
with staleness-decayed weights, and a virtual clock charged from *measured*
wire bytes.  See `runner.SimRunner` for the entry point.
"""
from .clients import (COHORT_SAMPLERS, ClientPopulation, SAMPLERS,
                      cohort_available, cohort_uniform, floyd_sample,
                      sample_available, sample_uniform)
from .clock import CohortTiming, RoundTiming, VirtualClock
from .history import SimHistory
from .runner import CohortRunner, SimRunner
from .scheduler import (AsyncBufferScheduler, CohortPlan, RoundPlan,
                        SyncScheduler)

__all__ = [
    "AsyncBufferScheduler", "COHORT_SAMPLERS", "ClientPopulation",
    "CohortPlan", "CohortRunner", "CohortTiming", "RoundPlan", "RoundTiming",
    "SAMPLERS", "SimHistory", "SimRunner", "SyncScheduler", "VirtualClock",
    "cohort_available", "cohort_uniform", "floyd_sample", "sample_available",
    "sample_uniform",
]
