"""Simulation history: accuracy against *virtual wallclock* and measured
cumulative bytes — the paper's Figs. 5-8 axes (cumulative upload time), which
a round-indexed history cannot produce.

Each record merges the engine's per-round metrics (losses, test accuracy)
with the scheduler's timing (round duration, cumulative virtual seconds,
participants, staleness) and the measured wire-byte ledger (per-leg uplink/
downlink bytes actually charged, cumulative).  JSON round-trippable for
checkpointing and for the benchmark plots.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field


@dataclass
class SimHistory:
    records: list = field(default_factory=list)

    def append(self, rec: dict) -> None:
        self.records.append(rec)

    def __len__(self) -> int:
        return len(self.records)

    def __getitem__(self, i):
        return self.records[i]

    def __iter__(self):
        return iter(self.records)

    def series(self, key: str) -> list:
        return [r[key] for r in self.records if key in r]

    # ---------------------------------------------- paper Fig. 5-8 queries --
    def time_to(self, target: float, key: str = "test_acc") -> float | None:
        """Virtual seconds until ``key`` first reaches ``target``."""
        for r in self.records:
            if r.get(key, -float("inf")) >= target:
                return r["t_cum"]
        return None

    def bytes_to(self, target: float, key: str = "test_acc") -> int | None:
        """Cumulative wire bytes until ``key`` first reaches ``target``
        (the paper's ComU@acc metric, on the virtual-time axis)."""
        for r in self.records:
            if r.get(key, -float("inf")) >= target:
                return r["cum_bytes"]
        return None

    # ------------------------------------------------------------ ckpt I/O --
    def to_json(self) -> str:
        return json.dumps(self.records, default=float)

    @classmethod
    def from_json(cls, s: str) -> "SimHistory":
        return cls(records=json.loads(s))
