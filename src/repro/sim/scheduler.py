"""Round schedulers: who participates, how stale they are, what time it is.

Both schedulers emit a `RoundPlan` per aggregation round — a participation
mask, per-client staleness, and the virtual-time window — which `SimRunner`
injects into the jitted round as ``BatchCtx.mask`` / ``.stale`` (the
aggregation then gives absent clients exactly zero weight and decays stale
contributions by ``staleness_decay**stale``; see `core.aggregation`).

* `SyncScheduler` — FedAvg-style deadline rounds: sample a cohort, wait for
  the slowest on-time member (or the straggler deadline).  Late clients are
  either dropped or admitted into the *next* round with staleness 1+.
* `AsyncBufferScheduler` — FedBuff-style: every client trains continuously
  at its own pace; the server aggregates whenever ``buffer_size`` uploads
  have arrived.  A client that last synced at aggregation j and arrives at
  aggregation j' contributes with staleness j' - j - 1.

State (virtual clock, pending/arrival arrays, counters) is exposed via
``state()``/``set_state()`` dicts so a checkpointed simulation resumes on
the same wallclock axis.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field

import numpy as np

from ..obs import trace as obs
from .clients import COHORT_SAMPLERS, SAMPLERS, ClientPopulation
from .clock import VirtualClock


def _publish_plan(n_participants: int, n_dropped: int, t_end: float) -> None:
    """Scheduler-side metrics: cohort sizes, straggler drops, and the
    virtual clock, published into the installed registry (no-op without
    one — a single global read per planned round)."""
    reg = obs.current_registry()
    if reg is not None:
        reg.counter("sched.rounds_planned").inc()
        reg.counter("sched.dropped").inc(n_dropped)
        reg.histogram("sched.participants",
                      bounds=tuple(float(2 ** i)
                                   for i in range(21))).observe(n_participants)
        reg.gauge("sched.virtual_time_s").set(t_end)


@dataclass(frozen=True)
class RoundPlan:
    """One aggregation round's participation and timing."""
    mask: np.ndarray           # (K,) bool — whose upload enters aggregation
    staleness: np.ndarray      # (K,) int — label lag of each contribution
    t_start: float
    t_end: float
    dropped: np.ndarray        # (K,) bool — selected but cut by the deadline

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    @property
    def n_participants(self) -> int:
        return int(self.mask.sum())


@dataclass(frozen=True)
class CohortPlan:
    """`RoundPlan`'s O(m) form: sorted global ids instead of (K,) arrays —
    the only participation record the cohort-resident path ever holds, so
    planning a round costs O(m log K) regardless of fleet size.  Densify
    with ``dense_mask`` only in small-K parity tests."""
    ids: np.ndarray            # (m,) int64 sorted — whose upload aggregates
    staleness: np.ndarray      # (m,) int64 aligned with ``ids``
    t_start: float
    t_end: float
    dropped_ids: np.ndarray    # (d,) int64 — selected but cut by the deadline

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    @property
    def n_participants(self) -> int:
        return int(self.ids.size)

    def dense_mask(self, K: int) -> np.ndarray:
        mask = np.zeros(K, bool)
        mask[self.ids] = True
        return mask

    def dense_staleness(self, K: int) -> np.ndarray:
        stale = np.zeros(K, np.int64)
        stale[self.ids] = self.staleness
        return stale


@dataclass
class SyncScheduler:
    """Synchronous deadline rounds over a `ClientPopulation`.

    ``fraction`` of the K clients is sampled each round (``sampler`` is
    "uniform" or the availability-weighted "available"); ``deadline`` (in
    virtual seconds) cuts stragglers, which are dropped (``straggler=
    "drop"``) or admitted late into the next round (``"admit"``) carrying
    staleness >= 1.  ``idealized`` is True when the configuration can never
    produce a mask or staleness — `SimRunner` then leaves the BatchCtx
    untouched and the round is bit-for-bit the plain-engine round."""
    population: ClientPopulation
    fraction: float = 1.0
    deadline: float | None = None
    straggler: str = "drop"              # drop | admit
    sampler: str = "uniform"
    clock: VirtualClock = field(default_factory=VirtualClock)
    _pending_since: np.ndarray = None    # (K,) agg round a late upload is
    #                                      from; -1 = no pending upload
    _pending: dict = None                # cohort path: {id: agg round} — the
    #                                      O(#pending) form of the same book
    _round: int = 0

    # sync participation depends only on the per-round rng and the measured
    # leg bytes — never on training results — so a whole chunk of RoundPlans
    # can be drawn up front and fed through the engine's compiled
    # `chunk_rounds` scan as a (k, K) mask/stale plan (`SimRunner`)
    plannable = True

    def __post_init__(self):
        if self.straggler not in ("drop", "admit"):
            raise ValueError(self.straggler)
        if self.sampler not in SAMPLERS:
            raise ValueError(self.sampler)
        if self._pending_since is None:
            self._pending_since = np.full(self.population.n_clients, -1,
                                          np.int64)
        if self._pending is None:
            self._pending = {}

    @property
    def idealized(self) -> bool:
        return (self.fraction >= 1.0 and self.deadline is None
                and (self.sampler == "uniform"
                     or bool(np.all(self.population.availability >= 1.0))))

    @property
    def active_budget(self) -> int:
        """Static upper bound on per-round participants — the m of the
        participation-sparse round plane (``BatchCtx.active_budget``).  A
        sampled cohort is at most ceil(fraction * K); under ``straggler=
        "admit"`` the previous round's deadline-cut clients (a subset of its
        cohort) can join on top, so the bound doubles.  Every `RoundPlan`
        this scheduler emits satisfies ``mask.sum() <= active_budget`` by
        construction (property-tested in tests/test_sim_props.py)."""
        K = self.population.n_clients
        m = min(K, max(1, math.ceil(self.fraction * K)))
        if self.deadline is not None and self.straggler == "admit":
            m = min(K, 2 * m)
        return m

    def next_round(self, rng: np.random.Generator, up_bytes: float,
                   down_bytes: float) -> RoundPlan:
        pop = self.population
        t0 = self.clock.now
        selected = SAMPLERS[self.sampler](rng, pop, self.fraction)
        timing = self.clock.charge_sync_round(
            selected, pop.latency(up_bytes, down_bytes), self.deadline)

        pending = self._pending_since >= 0
        mask = timing.on_time | pending
        staleness = np.zeros(pop.n_clients, np.int64)
        staleness[pending] = self._round - self._pending_since[pending]
        self._pending_since[pending] = -1
        if self.straggler == "admit":
            # a late upload was computed from this round's broadcast labels:
            # it joins the next aggregation at staleness >= 1
            self._pending_since[timing.dropped] = self._round
        self._round += 1
        _publish_plan(int(mask.sum()), int(timing.dropped.sum()),
                      self.clock.now)
        return RoundPlan(mask, staleness, t0, self.clock.now, timing.dropped)

    def next_cohort(self, rng: np.random.Generator, up_bytes: float,
                    down_bytes: float) -> CohortPlan:
        """`next_round`'s O(m log K) form: the cohort is drawn as ids
        (`clients.COHORT_SAMPLERS` — Floyd / cached-CDF, no K-length
        workspace), latency is charged for the m members only, and the
        late-upload book is a dict keyed by id.  Same deadline / straggler
        semantics; the sampler draws differ from `next_round`'s mask
        samplers (different rng consumption), so the two forms describe
        the same fleet model, not the same realized rounds."""
        pop = self.population
        t0 = self.clock.now
        cohort = COHORT_SAMPLERS[self.sampler](rng, pop, self.fraction)
        timing = self.clock.charge_cohort(
            pop.latency_ids(cohort, up_bytes, down_bytes), self.deadline)
        on_time = cohort[timing.on_time]
        dropped = cohort[timing.dropped]

        # pending late uploads join this aggregation, stale by their lag;
        # a client both pending and freshly on-time keeps the pending lag
        # (mirrors the dense book, which overwrites fresh staleness 0)
        stale_of = {int(i): self._round - since
                    for i, since in self._pending.items()}
        self._pending.clear()
        ids = np.union1d(on_time, np.fromiter(stale_of, np.int64,
                                              len(stale_of)))
        staleness = np.array([stale_of.get(int(i), 0) for i in ids], np.int64)
        if self.straggler == "admit":
            for i in dropped:
                self._pending[int(i)] = self._round
        self._round += 1
        _publish_plan(int(ids.size), int(dropped.size), self.clock.now)
        return CohortPlan(ids, staleness, t0, self.clock.now, dropped)

    # ---------------------------------------------------------- checkpoint --
    def state(self) -> dict:
        return {"now": self.clock.now, "round": self._round,
                "pending_since": self._pending_since.tolist(),
                "pending": {str(k): int(v)
                            for k, v in self._pending.items()}}

    def set_state(self, s: dict) -> None:
        self.clock.now = float(s["now"])
        self._round = int(s["round"])
        self._pending_since = np.asarray(s["pending_since"], np.int64)
        self._pending = {int(k): int(v)
                         for k, v in s.get("pending", {}).items()}


@dataclass
class AsyncBufferScheduler:
    """Buffered-asynchronous aggregation (FedBuff-style).

    All clients train continuously; client k's upload lands every
    ``latency_k`` virtual seconds (lognormal jitter ``jitter_sigma`` per
    leg).  The server aggregates as soon as ``buffer_size`` uploads are
    buffered; contributors restart from the fresh broadcast, everyone else
    keeps training on the stale labels they last received — their eventual
    contribution is decayed by the algorithm's ``staleness_decay``."""
    population: ClientPopulation
    buffer_size: int = 2
    jitter_sigma: float = 0.0
    clock: VirtualClock = field(default_factory=VirtualClock)
    _arrival: np.ndarray = None          # (K,) next upload landing time
    _labels_from: np.ndarray = None      # dense path: (K,) label version
    #                                      each client trains against
    _heap: list = None                   # cohort path: (arrival, id) heap of
    #                                      MATERIALIZED arrivals only
    _labels: dict = None                 # cohort path: {id: label version} —
    #                                      O(#touched) form of the same book
    _cal: dict = None                    # cohort path: calendar-queue cursor
    #                                      (scalars only; see next_cohort)
    _round: int = 0

    idealized = False   # masks/staleness are structural in async mode
    plannable = False   # buffered-async rounds stay on the per-round path

    # how many equal-population (quantile) latency bands the calendar splits
    # the fleet into; each band materializes its heap entries only when the
    # pop frontier reaches its start time
    CAL_BUCKETS = 64

    @property
    def active_budget(self) -> int:
        """Exactly ``buffer_size`` uploads enter every aggregation, so the
        sparse round plane's budget is M — FedBuff-style async is the regime
        where computing only the active clients pays off most (M << K)."""
        return self.buffer_size

    def __post_init__(self):
        K = self.population.n_clients
        if not 1 <= self.buffer_size <= K:
            raise ValueError(f"buffer_size {self.buffer_size} not in [1, {K}]")
        if self._labels is None:
            self._labels = {}

    def _latency(self, rng, up_bytes, down_bytes) -> np.ndarray:
        lat = self.population.latency(up_bytes, down_bytes)
        if self.jitter_sigma > 0:
            lat = lat * rng.lognormal(0.0, self.jitter_sigma,
                                      self.population.n_clients)
        return lat

    def next_round(self, rng: np.random.Generator, up_bytes: float,
                   down_bytes: float) -> RoundPlan:
        K = self.population.n_clients
        if self._arrival is None:        # everyone starts training at t=0
            self._arrival = self._latency(rng, up_bytes, down_bytes)
        if self._labels_from is None:    # dense book, lazily (dense path only)
            self._labels_from = np.zeros(K, np.int64)
        t0 = self.clock.now
        order = np.argsort(self._arrival, kind="stable")
        idx = order[:self.buffer_size]
        t_agg = float(self._arrival[idx].max())
        self.clock.advance(max(0.0, t_agg - t0))

        mask = np.zeros(K, bool)
        mask[idx] = True
        staleness = np.zeros(K, np.int64)
        staleness[idx] = self._round - self._labels_from[idx]
        # contributors restart from the fresh broadcast (label version r+1)
        self._labels_from[idx] = self._round + 1
        self._arrival[idx] = (self.clock.now
                              + self._latency(rng, up_bytes, down_bytes)[idx])
        self._round += 1
        _publish_plan(int(mask.sum()), 0, self.clock.now)
        return RoundPlan(mask, staleness, t0, self.clock.now,
                         np.zeros(K, bool))

    def _open_bucket(self, rng: np.random.Generator) -> None:
        """Materialize the next calendar bucket: the vectorized numpy filter
        selects the ids whose BASE latency falls in the band, their (jittered)
        first arrivals become heap entries, and the cursor advances.  The
        (K,) base-latency vector is recomputed from the `ClientPopulation`
        model each opening — a transient vectorized pass, so the scheduler
        itself never holds per-client arrival state for untouched clients."""
        cal = self._cal
        j = cal["next"]
        lat = self.population.latency(cal["up"], cal["down"])
        bounds = cal["bounds"]
        if j == len(bounds) - 2:
            sel = lat >= bounds[j]       # last band is closed at hi
        else:
            sel = (lat >= bounds[j]) & (lat < bounds[j + 1])
        ids = np.flatnonzero(sel)
        t = lat[ids]
        if self.jitter_sigma > 0 and ids.size:
            t = t * rng.lognormal(0.0, self.jitter_sigma, ids.size)
        for i, ti in zip(ids, t):
            heapq.heappush(self._heap, (float(ti), int(i)))
        cal["next"] = j + 1

    def next_cohort(self, rng: np.random.Generator, up_bytes: float,
                    down_bytes: float) -> CohortPlan:
        """`next_round`'s lazy calendar-queue form (ROADMAP Open item 2b).

        The heap holds only MATERIALIZED arrivals: clients that already
        contributed (their re-armed next upload) plus the clients whose
        first arrival falls in an already-opened calendar bucket.  The
        first call computes only the ``CAL_BUCKETS + 1`` quantile boundaries
        of the base-latency distribution (equal-*population* bands, so a
        heavy-tailed fleet can't collapse into one band), and each band's
        first arrivals are materialized (`_open_bucket`) only when the pop
        frontier reaches its start time.  A million-client fleet whose
        simulation aggregates R rounds therefore holds O(popped + opened
        bands) heap entries instead of an eagerly heapified K, and the
        label-version book is an O(#touched) dict.

        Pops and re-arms stay O(M log heap) per round; a pop is taken only
        when no unopened band could still hold an earlier first arrival
        (``heap[0] < next band's start``).  Ties break on the lower id,
        matching the dense path's stable argsort.  With ``jitter_sigma=0``
        realized rounds equal `next_round`'s exactly (the pinned parity);
        with jitter a first arrival can land outside its base-latency band
        but is still released when the BASE band opens, so the realized
        stream is a valid sample of the same fleet model without a
        touched-set — it just differs from the eager-heap draw.  Use
        either form on one scheduler instance, not both (separate books).
        """
        pop = self.population
        if self._cal is None:            # everyone starts training at t=0:
            # O(n_buckets) QUANTILE boundaries, not equal-width bands — a
            # heavy-tailed fleet (lognormal compute) would put most of its
            # mass in the first linear band, re-eagerizing the queue; equal
            # *population* bands keep every opening ~K/n_buckets.  The (K,)
            # base-latency pass is transient; only the boundaries persist.
            lat = pop.latency(up_bytes, down_bytes)
            n_b = int(min(self.CAL_BUCKETS,
                          max(1, pop.n_clients // max(1, self.buffer_size))))
            bounds = np.quantile(lat, np.linspace(0.0, 1.0, n_b + 1))
            self._cal = {"bounds": [float(b) for b in bounds],
                         "next": 0, "up": float(up_bytes),
                         "down": float(down_bytes)}
            self._heap = []
        t0 = self.clock.now
        cal, popped = self._cal, []
        n_b = len(cal["bounds"]) - 1
        for _ in range(self.buffer_size):
            while cal["next"] < n_b and (
                    not self._heap
                    or self._heap[0][0] >= cal["bounds"][cal["next"]]):
                self._open_bucket(rng)
            popped.append(heapq.heappop(self._heap))
        self.clock.advance(max(0.0, max(t for t, _ in popped) - t0))
        ids = np.array(sorted(i for _, i in popped), np.int64)
        staleness = np.array([self._round - self._labels.get(int(i), 0)
                              for i in ids], np.int64)
        for i in ids:
            self._labels[int(i)] = self._round + 1
        lat = pop.latency_ids(ids, up_bytes, down_bytes)
        if self.jitter_sigma > 0:
            lat = lat * rng.lognormal(0.0, self.jitter_sigma, ids.size)
        for i, t in zip(ids, lat):
            heapq.heappush(self._heap, (self.clock.now + float(t), int(i)))
        self._round += 1
        _publish_plan(int(ids.size), 0, self.clock.now)
        return CohortPlan(ids, staleness, t0, self.clock.now,
                          np.zeros(0, np.int64))

    # ---------------------------------------------------------- checkpoint --
    def state(self) -> dict:
        """Everything the two arrival books need to resume: the dense path's
        (K,) arrays, and the cohort path's O(#touched) heap + label dict +
        calendar cursor (scalars).  An untouched book serializes as None/{}
        so a million-client cohort checkpoint stays O(#touched)."""
        return {"now": self.clock.now, "round": self._round,
                "arrival": (None if self._arrival is None
                            else self._arrival.tolist()),
                "labels_from": (None if self._labels_from is None
                                else self._labels_from.tolist()),
                "heap": (None if self._heap is None
                         else [[t, int(i)] for t, i in self._heap]),
                "labels": {str(k): int(v) for k, v in self._labels.items()},
                "cal": (None if self._cal is None else dict(self._cal))}

    def set_state(self, s: dict) -> None:
        self.clock.now = float(s["now"])
        self._round = int(s["round"])
        self._arrival = (None if s["arrival"] is None
                         else np.asarray(s["arrival"], np.float64))
        lf = s.get("labels_from")
        self._labels_from = (None if lf is None
                             else np.asarray(lf, np.int64))
        heap = s.get("heap")
        self._heap = (None if heap is None
                      else [(float(t), int(i)) for t, i in heap])
        if self._heap is not None:
            heapq.heapify(self._heap)
        self._labels = {int(k): int(v)
                        for k, v in s.get("labels", {}).items()}
        cal = s.get("cal")
        self._cal = None if cal is None else {
            "bounds": [float(b) for b in cal["bounds"]],
            "next": int(cal["next"]),
            "up": float(cal["up"]), "down": float(cal["down"])}
