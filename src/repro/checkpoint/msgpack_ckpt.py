"""Msgpack pytree checkpointing (no orbax/flax dependency).

Leaves are stored as {dtype, shape, raw bytes}; the tree structure is encoded
as nested msgpack maps/lists.  ``load_pytree`` optionally device_puts each
leaf to a target sharding (sharding-aware restore for the launcher)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_LEAF = "__leaf__"


def _pack(tree):
    if isinstance(tree, dict):
        return {str(k): _pack(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return {"__seq__": [_pack(v) for v in tree],
                "__tuple__": isinstance(tree, tuple)}
    arr = np.asarray(tree)
    return {_LEAF: True, "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}


def _unpack(node, shard_fn=None):
    if isinstance(node, dict) and node.get(_LEAF):
        arr = np.frombuffer(node["data"], dtype=node["dtype"]
                            ).reshape(node["shape"])
        if shard_fn is not None:
            return shard_fn(arr)
        return jnp.asarray(arr)
    if isinstance(node, dict) and "__seq__" in node:
        seq = [_unpack(v, shard_fn) for v in node["__seq__"]]
        return tuple(seq) if node.get("__tuple__") else seq
    return {k: _unpack(v, shard_fn) for k, v in node.items()}


def save_pytree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(_pack(tree), use_bin_type=True))
    os.replace(tmp, path)


def load_pytree(path: str, shardings=None):
    """shardings: optional pytree of jax.sharding.Sharding matching the file's
    structure; leaves are placed directly onto their shards."""
    with open(path, "rb") as f:
        raw = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
    if shardings is None:
        return _unpack(raw)
    tree = _unpack(raw)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
