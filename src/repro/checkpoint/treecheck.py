"""Pytree compatibility checking with *named* errors.

Loading a checkpoint from a different architecture/config into a live
engine used to surface as a raw pytree error (wrong leaf count) or — worse —
unflatten silently and explode later inside a jit with a shape mismatch.
Both `FedEngine.load_state` and the serving hot-swap path
(`repro.serve.ServeEngine.swap_weights`) route through these helpers so the
failure names the offending leaves instead.
"""
from __future__ import annotations

import jax

_MAX_NAMED = 8   # cap the error listing; a different arch mismatches ~everything


def _path_str(path) -> str:
    """'clients.params.embed.w'-style rendering of a KeyPath."""
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(f"[{p.idx}]")
        else:
            out.append(str(p))
    return ".".join(out) or "<root>"


def tree_mismatches(like, tree) -> list[str]:
    """Human-readable differences between ``tree`` and the reference
    ``like``: structure first, then per-leaf shape/dtype diffs (paths named
    from ``like``).  Empty list == fully compatible."""
    like_leaves, like_def = jax.tree_util.tree_flatten_with_path(like)
    leaves, tdef = jax.tree_util.tree_flatten(tree)
    if jax.tree_util.tree_structure(like) != tdef:
        msgs = [f"tree structure differs: expected {len(like_leaves)} leaves "
                f"({like_def}), got {len(leaves)} leaves ({tdef})"]
        return msgs
    msgs = []
    for (path, a), b in zip(like_leaves, leaves):
        a_shape, b_shape = tuple(a.shape), tuple(b.shape)
        a_dt, b_dt = str(a.dtype), str(b.dtype)
        if a_shape != b_shape or a_dt != b_dt:
            msgs.append(f"{_path_str(path)}: expected {a_shape} {a_dt}, "
                        f"got {b_shape} {b_dt}")
    if len(msgs) > _MAX_NAMED:
        msgs = msgs[:_MAX_NAMED] + [f"... and {len(msgs) - _MAX_NAMED} more"]
    return msgs


def assert_tree_compatible(like, tree, what: str = "pytree") -> None:
    """Raise ``ValueError`` naming every mismatched leaf if ``tree`` does not
    match ``like`` in structure, leaf shapes, and leaf dtypes."""
    msgs = tree_mismatches(like, tree)
    if msgs:
        raise ValueError(
            f"{what} does not match the expected pytree "
            f"(same arch/config?):\n  " + "\n  ".join(msgs))
