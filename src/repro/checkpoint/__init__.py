from .msgpack_ckpt import load_pytree, save_pytree  # noqa
from .treecheck import assert_tree_compatible, tree_mismatches  # noqa
