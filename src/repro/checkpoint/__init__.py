from .msgpack_ckpt import load_pytree, save_pytree  # noqa
