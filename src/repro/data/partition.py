"""Federated data partitioners (paper §4.1 "Data partitions").

* ``iid``            - shuffle, split into K equal shards.
* ``shard_non_iid``  - the paper's strong non-IID: sort by label, cut into
                       ``shards_per_client * K`` shards, deal S per client
                       (McMahan et al. scheme; S=2 in the paper).
* ``dirichlet``      - Dirichlet(alpha) label-skew (weak..strong via alpha).
* ``ratio_non_iid``  - 2-class 9:1/1:9 split (the paper's IMDb partition).
All return index arrays (K, I_k) so callers can gather fixed-size stacks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def iid(key, n: int, K: int) -> jnp.ndarray:
    per = n // K
    perm = jax.random.permutation(key, n)
    return perm[: per * K].reshape(K, per)


def shard_non_iid(key, labels, K: int, shards_per_client: int = 2):
    """Paper's strong non-IID: each client ends up with ~shards_per_client
    distinct classes."""
    n = labels.shape[0]
    S = shards_per_client * K
    shard_size = n // S
    order = jnp.argsort(labels, stable=True)
    shards = order[: S * shard_size].reshape(S, shard_size)
    assign = jax.random.permutation(key, S).reshape(K, shards_per_client)
    return shards[assign].reshape(K, shards_per_client * shard_size)


def dirichlet(key, labels, K: int, alpha: float, n_classes: int):
    """Label-skew partition; returns equal-size index stacks (truncated)."""
    labels_np = np.asarray(labels)
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    idx_by_class = [np.where(labels_np == c)[0] for c in range(n_classes)]
    for a in idx_by_class:
        rng.shuffle(a)
    client_lists = [[] for _ in range(K)]
    for c in range(n_classes):
        props = rng.dirichlet(np.full(K, alpha))
        cuts = (np.cumsum(props) * len(idx_by_class[c])).astype(int)[:-1]
        for k, part in enumerate(np.split(idx_by_class[c], cuts)):
            client_lists[k].extend(part.tolist())
    size = min(len(l) for l in client_lists)
    out = np.stack([rng.permutation(np.array(l))[:size] for l in client_lists])
    return jnp.asarray(out, jnp.int32)


def ratio_non_iid(key, labels, K: int, major_ratio: float = 0.9):
    """Binary-task partition: half the clients are 9:1 positive, half 1:9."""
    labels_np = np.asarray(labels)
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    pos = rng.permutation(np.where(labels_np == 1)[0])
    neg = rng.permutation(np.where(labels_np == 0)[0])
    per = len(labels_np) // K
    n_major = int(per * major_ratio)
    n_minor = per - n_major
    out, pi, ni = [], 0, 0
    for k in range(K):
        if k % 2 == 0:
            sel = np.concatenate([pos[pi:pi + n_major], neg[ni:ni + n_minor]])
            pi += n_major
            ni += n_minor
        else:
            sel = np.concatenate([neg[ni:ni + n_major], pos[pi:pi + n_minor]])
            ni += n_major
            pi += n_minor
        out.append(rng.permutation(sel))
    return jnp.asarray(np.stack(out), jnp.int32)


def gather_clients(x, y, idx):
    """idx: (K, I) -> stacked client arrays (K, I, ...)."""
    return jnp.take(x, idx, axis=0), jnp.take(y, idx, axis=0)
