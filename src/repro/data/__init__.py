from . import partition, pipeline, synthetic  # noqa
