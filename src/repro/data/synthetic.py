"""Synthetic datasets (the container is offline; MNIST/F-MNIST/IMDb/Reuters
are replaced by structurally-analogous procedural data, see DESIGN.md §7).

* ``digits``      - 10-class image task (MNIST stand-in): smooth per-class
                    templates + affine jitter + pixel noise.  Classes share
                    low-frequency structure so inter-class similarity exists
                    (the property knowledge distillation relies on).
* ``fashion_noise`` - a *different* template family (plays Fashion-MNIST's
                    role as foreign/noisy/backdoor data).
* ``bow``         - Reuters stand-in: class-conditional sparse bag-of-words.
* ``token_lm``    - synthetic LM streams: per-domain Markov chains over a
                    Zipf vocabulary (non-IID across domains/clients).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------------ images ---
def _templates(seed: int, n_classes: int, hw: int, grid: int = 4) -> np.ndarray:
    """Smooth class templates: bilinear-upsampled random coarse grids."""
    rng = np.random.default_rng(seed)
    coarse = rng.normal(size=(n_classes, grid, grid)).astype(np.float32)
    # bilinear upsample to (hw, hw)
    xs = np.linspace(0, grid - 1, hw)
    x0 = np.clip(np.floor(xs).astype(int), 0, grid - 2)
    fx = (xs - x0).astype(np.float32)
    rows = (coarse[:, x0] * (1 - fx[None, :, None])
            + coarse[:, x0 + 1] * fx[None, :, None])          # (C, hw, grid)
    cols = (rows[:, :, x0] * (1 - fx[None, None, :])
            + rows[:, :, x0 + 1] * fx[None, None, :])         # (C, hw, hw)
    t = cols - cols.mean(axis=(1, 2), keepdims=True)
    return t / (t.std(axis=(1, 2), keepdims=True) + 1e-6)


def make_digits(key, n: int, n_classes: int = 10, hw: int = 16,
                template_seed: int = 1234, noise: float = 0.35):
    """Returns x: (n, hw, hw, 1) float32, y: (n,) int32."""
    kc, ks, kn = jax.random.split(key, 3)
    templates = jnp.asarray(_templates(template_seed, n_classes, hw))
    y = jax.random.randint(kc, (n,), 0, n_classes)
    base = templates[y]                                       # (n, hw, hw)
    shifts = jax.random.randint(ks, (n, 2), -2, 3)

    def jitter(img, sh):
        return jnp.roll(jnp.roll(img, sh[0], axis=0), sh[1], axis=1)

    imgs = jax.vmap(jitter)(base, shifts)
    imgs = imgs + noise * jax.random.normal(kn, imgs.shape)
    return imgs[..., None].astype(jnp.float32), y.astype(jnp.int32)


def make_fashion_noise(key, n: int, n_classes: int = 10, hw: int = 16):
    """Foreign image family (different template seed + sharper texture)."""
    x, y = make_digits(key, n, n_classes, hw, template_seed=777, noise=0.5)
    kh = jax.random.fold_in(key, 99)
    texture = jax.random.normal(kh, x.shape) * 0.4
    return (x + jnp.sign(texture) * 0.3).astype(jnp.float32), y


# ------------------------------------------------------------------- bow -----
def make_bow(key, n: int, n_classes: int = 20, vocab: int = 1000,
             words_per_doc: int = 40):
    """Class-conditional sparse binary bag-of-words (Reuters stand-in)."""
    kt, kd, kw = jax.random.split(key, 3)
    topic = jax.random.dirichlet(kt, jnp.ones((vocab,)) * 0.05, (n_classes,))
    y = jax.random.randint(kd, (n,), 0, n_classes)
    docs = jax.vmap(
        lambda k, p: jnp.zeros((vocab,)).at[
            jax.random.choice(k, vocab, (words_per_doc,), p=p)].set(1.0)
    )(jax.random.split(kw, n), topic[y])
    return docs.astype(jnp.float32), y.astype(jnp.int32)


# --------------------------------------------------------------- token LM ----
def make_token_lm(key, n_seqs: int, seq_len: int, vocab: int,
                  n_domains: int = 4, order_mix: float = 0.7):
    """Synthetic LM corpus: each sequence follows a domain-specific first-order
    Markov chain mixed with a Zipf unigram; domain id doubles as the non-IID
    partition key.  Returns tokens (n_seqs, seq_len) int32, domains (n_seqs,)."""
    kd, kt = jax.random.split(key)
    domains = jax.random.randint(kd, (n_seqs,), 0, n_domains)
    rng = np.random.default_rng(4321)
    zipf = 1.0 / (np.arange(1, vocab + 1) ** 1.1)
    zipf /= zipf.sum()
    # per-domain block-diagonal-ish transition bias
    doms = []
    for d in range(n_domains):
        lo = (vocab * d) // n_domains
        hi = (vocab * (d + 1)) // n_domains
        p = zipf.copy()
        p[lo:hi] *= 20.0
        doms.append(p / p.sum())
    dom_p = jnp.asarray(np.stack(doms), jnp.float32)          # (D, V)

    def gen_seq(k, d):
        p = dom_p[d]

        def step(carry, kk):
            prev = carry
            mix = order_mix * p + (1 - order_mix) \
                * jax.nn.one_hot((prev * 7 + 13) % vocab, vocab)
            nxt = jax.random.choice(kk, vocab, p=mix)
            return nxt, nxt

        k0, kr = jax.random.split(k)
        first = jax.random.choice(k0, vocab, p=p)
        _, toks = jax.lax.scan(step, first, jax.random.split(kr, seq_len - 1))
        return jnp.concatenate([first[None], toks])

    tokens = jax.vmap(gen_seq)(jax.random.split(kt, n_seqs), domains)
    return tokens.astype(jnp.int32), domains.astype(jnp.int32)
