"""Dataset assembly for federated experiments: private/open split, client
stacks, and LLM-scale token batching."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import partition, synthetic


@dataclass
class FederatedImageTask:
    x_clients: jax.Array      # (K, I_k, H, W, 1)
    y_clients: jax.Array      # (K, I_k)
    open_x: jax.Array         # (I_o, H, W, 1)
    x_test: jax.Array
    y_test: jax.Array
    n_classes: int


def build_image_task(seed: int, K: int, n_private: int, n_open: int,
                     n_test: int, distribution: str = "non_iid",
                     hw: int = 16, n_classes: int = 10,
                     noisy_open: int = 0) -> FederatedImageTask:
    key = jax.random.PRNGKey(seed)
    kp, ko, kt, kd, kn = jax.random.split(key, 5)
    x, y = synthetic.make_digits(kp, n_private, n_classes, hw)
    open_x, _ = synthetic.make_digits(ko, n_open, n_classes, hw)
    x_test, y_test = synthetic.make_digits(kt, n_test, n_classes, hw)
    if distribution == "iid":
        idx = partition.iid(kd, n_private, K)
    elif distribution == "non_iid":
        idx = partition.shard_non_iid(kd, y, K, 2)
    elif distribution.startswith("dirichlet"):
        alpha = float(distribution.split(":")[1])
        idx = partition.dirichlet(kd, y, K, alpha, n_classes)
    else:
        raise ValueError(distribution)
    xc, yc = partition.gather_clients(x, y, idx)
    if noisy_open:
        noise_x, _ = synthetic.make_fashion_noise(kn, noisy_open, n_classes, hw)
        from ..core.attacks import mix_noisy_open
        open_x = mix_noisy_open(open_x, noise_x, kn)
    return FederatedImageTask(xc, yc, open_x, x_test, y_test, n_classes)


# -------------------------------------------------- cohort data providers ----
@dataclass
class SlabTask:
    """An (S, ...)-slab data view with `FederatedImageTask`'s field names,
    so ``FedEngine.make_ctx`` reads a cohort slab exactly like a dense
    population — only the leading client axis means "slab lane" instead of
    "client id" (the mapping lives in ``BatchCtx.cohort``)."""
    x_clients: jax.Array
    y_clients: jax.Array
    open_x: jax.Array
    x_test: jax.Array = None
    y_test: jax.Array = None
    n_classes: int = 10


class ArrayProvider:
    """Cohort data provider over an in-memory dense task: ``slab(ids)``
    gathers the requested client rows.  The parity provider — a cohort run
    over it sees bitwise the rows a dense run sees (tests/test_cohort.py);
    real fleet-scale runs use a per-id generator like `SyntheticProvider`."""

    def __init__(self, task: FederatedImageTask):
        self.task = task
        self.n_clients = int(task.x_clients.shape[0])

    def slab(self, ids) -> SlabTask:
        import numpy as np
        ids = jnp.asarray(np.asarray(ids, np.int64))
        t = self.task
        return SlabTask(jnp.take(t.x_clients, ids, axis=0),
                        jnp.take(t.y_clients, ids, axis=0),
                        t.open_x, t.x_test, t.y_test, t.n_classes)


class SyntheticProvider:
    """Per-id on-demand synthetic image shards: client g's private data is a
    deterministic function of ``(seed, g)`` alone (``fold_in`` key), so a
    million-client fleet costs no data memory until a client is actually
    sampled — the provider the headline ``examples/sim_stragglers.py
    --clients 1000000`` run uses.  The shared open/test sets materialize
    once (they are O(1) in K)."""

    def __init__(self, seed: int, n_clients: int, n_per_client: int,
                 n_open: int, n_test: int = 0, hw: int = 16,
                 n_classes: int = 10):
        self.n_clients = int(n_clients)
        self.n_classes = n_classes
        key = jax.random.PRNGKey(seed)
        kp, ko, kt = jax.random.split(key, 3)
        self._kp = kp
        open_x, _ = synthetic.make_digits(ko, n_open, n_classes, hw)
        self.open_x = open_x
        if n_test:
            self.x_test, self.y_test = synthetic.make_digits(
                kt, n_test, n_classes, hw)
        else:
            self.x_test = self.y_test = None
        self._gen = jax.jit(jax.vmap(
            lambda k: synthetic.make_digits(k, n_per_client, n_classes, hw)))

    def slab(self, ids) -> SlabTask:
        import numpy as np
        ids = jnp.asarray(np.asarray(ids, np.int64), jnp.uint32)
        keys = jax.vmap(lambda i: jax.random.fold_in(self._kp, i))(ids)
        xc, yc = self._gen(keys)
        return SlabTask(xc, yc, self.open_x, self.x_test, self.y_test,
                        self.n_classes)


@dataclass
class FederatedLMTask:
    """LLM-scale federated task for `FedEngine`: batch dicts of token arrays
    instead of image tensors.  Labels derive from the tokens (next-token
    prediction), so ``y_clients`` stays an absent pytree slot."""
    x_clients: dict           # leaves (K, B, S, ...) private token stacks
    open_x: dict              # leaves (I_o, S, ...) the shared open set
    y_clients: tuple = ()


def build_lm_task(seed: int, K: int, batch: int, seq: int, vocab: int,
                  n_open: int | None = None,
                  extras_fn=None) -> FederatedLMTask:
    """``extras_fn(batch, key) -> dict`` adds modality inputs (vlm patches,
    audio frames); they are broadcast over the client axis and shared with
    the open set, mirroring the token layout."""
    key = jax.random.PRNGKey(seed)
    kd, ko, ke = jax.random.split(key, 3)
    private = lm_private_batches(kd, K, batch, seq, vocab)
    open_b = lm_open_batch(ko, n_open or batch, seq, vocab)
    if extras_fn is not None:
        ex = extras_fn(batch, ke)
        private.update({k: jnp.broadcast_to(v[None], (K,) + v.shape)
                        for k, v in ex.items()})
        open_b.update(ex)
    return FederatedLMTask(x_clients=private, open_x=open_b)


def lm_private_batches(key, n_clients: int, batch: int, seq: int, vocab: int):
    """Per-client private token batches for the pod-scale DS-FL round:
    domain d <-> client d (structurally non-IID)."""
    toks, dom = synthetic.make_token_lm(key, n_clients * batch, seq, vocab,
                                        n_domains=n_clients)
    order = jnp.argsort(dom, stable=True)
    return {"tokens": toks[order].reshape(n_clients, batch, seq)}


def lm_open_batch(key, batch: int, seq: int, vocab: int):
    toks, _ = synthetic.make_token_lm(key, batch, seq, vocab, n_domains=7)
    return {"tokens": toks}
