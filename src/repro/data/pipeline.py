"""Dataset assembly for federated experiments: private/open split, client
stacks, and LLM-scale token batching."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import partition, synthetic


@dataclass
class FederatedImageTask:
    x_clients: jax.Array      # (K, I_k, H, W, 1)
    y_clients: jax.Array      # (K, I_k)
    open_x: jax.Array         # (I_o, H, W, 1)
    x_test: jax.Array
    y_test: jax.Array
    n_classes: int


def build_image_task(seed: int, K: int, n_private: int, n_open: int,
                     n_test: int, distribution: str = "non_iid",
                     hw: int = 16, n_classes: int = 10,
                     noisy_open: int = 0) -> FederatedImageTask:
    key = jax.random.PRNGKey(seed)
    kp, ko, kt, kd, kn = jax.random.split(key, 5)
    x, y = synthetic.make_digits(kp, n_private, n_classes, hw)
    open_x, _ = synthetic.make_digits(ko, n_open, n_classes, hw)
    x_test, y_test = synthetic.make_digits(kt, n_test, n_classes, hw)
    if distribution == "iid":
        idx = partition.iid(kd, n_private, K)
    elif distribution == "non_iid":
        idx = partition.shard_non_iid(kd, y, K, 2)
    elif distribution.startswith("dirichlet"):
        alpha = float(distribution.split(":")[1])
        idx = partition.dirichlet(kd, y, K, alpha, n_classes)
    else:
        raise ValueError(distribution)
    xc, yc = partition.gather_clients(x, y, idx)
    if noisy_open:
        noise_x, _ = synthetic.make_fashion_noise(kn, noisy_open, n_classes, hw)
        from ..core.attacks import mix_noisy_open
        open_x = mix_noisy_open(open_x, noise_x, kn)
    return FederatedImageTask(xc, yc, open_x, x_test, y_test, n_classes)


def lm_private_batches(key, n_clients: int, batch: int, seq: int, vocab: int):
    """Per-client private token batches for the pod-scale DS-FL round:
    domain d <-> client d (structurally non-IID)."""
    toks, dom = synthetic.make_token_lm(key, n_clients * batch, seq, vocab,
                                        n_domains=n_clients)
    order = jnp.argsort(dom, stable=True)
    return {"tokens": toks[order].reshape(n_clients, batch, seq)}


def lm_open_batch(key, batch: int, seq: int, vocab: int):
    toks, _ = synthetic.make_token_lm(key, batch, seq, vocab, n_domains=7)
    return {"tokens": toks}
