"""Slot-based continuous-batching inference engine.

`ServeEngine` holds a fixed-capacity decode batch — ``slots`` lanes of the
existing ring-buffer KV / O(1) SSM decode cache (`models.api`) — and drives
it with a small, pinned set of compiled programs:

  * one **decode step** for all slots at once: per-slot token and position,
    vmapped over the slot axis of the batched cache, greedy argmax on
    device.  The slot count is static and free slots simply compute garbage
    lanes (the same static-shape discipline as `BatchCtx.active_budget`),
    so admitting and evicting requests never recompiles — one compile
    serves the server's whole lifetime, pinned by tests/test_serve.py.
  * one **fused decode chunk** per ``decode_chunk`` size used: ``step(now,
    decode_chunk=d)`` folds d decode steps into a single ``lax.scan`` —
    prompt-tail tokens are fed as a precomputed forced-token matrix,
    EOS/max-token finishers freeze their token/position inside the scan
    (finished lanes keep computing garbage, exactly the lane the host loop
    would have left behind), and the chunk pays **one host sync** instead
    of d.  Token-identical to d single steps; mid-chunk finishers are
    accounted at their true virtual sub-step time (``now + j * step_dt``)
    so latency percentiles are unchanged.  Each d is keyed separately in
    the jit cache, so toggling chunk sizes never recompiles.
  * one **prefill-insert** per prompt-length bucket: prefill the largest
    bucket-length *prefix* of the prompt in a single full-sequence shot,
    write the resulting one-request cache into the claimed slot
    (``dynamic_update_slice`` along the slot axis, slot index traced), and
    feed the short prompt tail through the normal decode step as forced
    tokens.  No prompt padding ever enters the model, so a request decodes
    **token-identically** to serving it alone; the bucket set only bounds
    how many prefill programs get compiled.  Bucket 1 is always a member,
    so prompts shorter than every configured bucket prefill their first
    token through the shared length-1 program instead of compiling one
    program per distinct short length (the compile set IS the bucket set).
  * one **batched prefill-insert** per (bucket, batch-size-class):
    ``insert_batch`` admits up to ``slots`` same-bucket requests in one
    compiled shot — the (m, n) token block prefills as one batch and the
    resulting per-request caches land via a traced slot-index *vector*
    (a vectorized ``dynamic_update_slice`` over the slot axis).  m is
    padded up to a power-of-two class (pad rows duplicate row 0 and write
    row 0's lane the identical values, so padding is order-free and
    token-exact), bounding compiles to one per (bucket, class).

Per-slot bookkeeping (prompt tail, generated tokens, timestamps) is plain
host Python: the device work per step is one dispatch returning the (N,)
argmax tokens — or, chunked, one dispatch returning the (d, N) token
matrix the host replays — the sync serving must pay anyway to emit
tokens, now amortized over d steps.

Weights are swapped live via ``swap_weights`` (see `repro.serve.swap` for
the `FedEngine` hook): treedefs/shapes must match the current serving
params (checked, mismatches named), the old buffers are donated to the
swap jit so the new weights land in their storage, and a version counter
is stamped onto every `Response` so callers can tell which federated
round's distilled model produced their tokens.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import assert_tree_compatible
from ..models.api import model_decode_step, model_init_cache, model_prefill
from ..models.base import ModelConfig
from ..obs import trace as obs
from ..obs.jit_watch import jit_cache_size  # canonical impl; re-exported
from .queue import Request, Response, bucket_of

DEFAULT_BUCKETS = (16, 32, 64, 128)


@dataclass
class _SlotTask:
    """Host-side state of one occupied slot."""
    req: Request
    pending: list                       # prompt-tail tokens not yet fed
    generated: list = field(default_factory=list)
    admitted_at: float = 0.0
    first_token_at: Optional[float] = None


class ServeEngine:
    """Continuous-batching greedy decoder over a fixed slot budget.

    ``seq_budget`` caps prompt + generation per request (it sizes the
    ring-buffer KV cache, so staying under it keeps full-context exactness).
    ``buckets`` are the compiled prefill lengths (see module docstring);
    bucket 1 is always added, so prompts shorter than every configured
    bucket prefill their first token through the shared length-1 program
    and force the rest through the decode step — the prefill compile set
    never grows beyond the bucket set.

    Token-only architectures (dense / moe / ssm / hybrid); the audio and
    vlm stubs need modality inputs a prompt doesn't carry.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 seq_budget: int = 128,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 eos_id: Optional[int] = None, version: int = 0):
        if cfg.arch_type in ("vlm", "audio"):
            raise NotImplementedError(
                f"ServeEngine serves token-only archs; {cfg.arch_type!r} "
                "needs modality inputs per request")
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        self.cfg = cfg
        self.params = params
        self.slots = int(slots)
        self.seq_budget = int(seq_budget)
        # bucket 1 is always a member: the short-prompt fallback compiles
        # the one shared length-1 prefill instead of one program per
        # distinct short length (the compile set == the bucket set)
        self.buckets = tuple(sorted({1} | {int(b) for b in buckets
                                           if b <= self.seq_budget}))
        self.eos_id = eos_id
        self.version = int(version)

        self.cache = model_init_cache(cfg, params, self.slots, self.seq_budget)
        self.tok = np.zeros((self.slots,), np.int32)
        self.pos = np.zeros((self.slots,), np.int32)
        self.tasks: list = [None] * self.slots
        self.completed: list = []       # drained by pop_completed()
        self.n_steps = 0                # decode sub-steps accounted
        self.n_dispatches = 0           # device round-trips those steps cost
        self.n_inserts = 0              # requests admitted
        self.n_prefill_shots = 0        # compiled prefill dispatches
        self.n_swaps = 0

        self._step_fn = self._build_step()
        self._chunk_fns: dict = {}      # decode_chunk d -> jitted fused scan
        self._prefill_fns: dict = {}    # prefill length -> jitted insert
        self._prefill_batch_fns: dict = {}   # (bucket, class) -> jitted

    # -------------------------------------------------------- compiled fns ---
    def _build_step(self):
        cfg = self.cfg

        def one(params, cache_i, tok_i, pos_i):
            cache_i = jax.tree.map(lambda a: jnp.expand_dims(a, 1), cache_i)
            logits, nc = model_decode_step(cfg, params, cache_i,
                                           tok_i[None], pos_i)
            return (jnp.argmax(logits[0]).astype(jnp.int32),
                    jax.tree.map(lambda a: jnp.squeeze(a, axis=1), nc))

        def step(params, cache, tok, pos):
            # vmap over the slot axis (axis 1 of every cache leaf: leaves are
            # (n_blocks, slots, ...)); each lane sees its own position, so
            # slots at different depths decode in the same dispatch
            return jax.vmap(one, in_axes=(None, 1, 0, 0),
                            out_axes=(0, 1))(params, cache, tok, pos)

        return jax.jit(step, donate_argnums=(1,))

    def _build_chunk(self, d: int):
        """d decode steps fused into one compiled ``lax.scan``.

        Carry: (cache, tok, pos, remaining, forced_len).  ``forced`` is the
        (d, N) prompt-tail matrix — sub-step j feeds ``forced[j, i]`` to
        lanes still consuming their tail; ``remaining`` counts tokens each
        lane still owes (0 == free or finished).  A lane that hits its
        max-token count (or EOS) mid-chunk freezes its token/position —
        bitwise the lane the per-step host loop leaves behind after
        eviction — and keeps computing garbage nothing reads, so the chunk
        shape never depends on who finishes when.  Output is the (d, N)
        argmax-token matrix: the chunk's single host sync."""
        cfg, eos = self.cfg, self.eos_id

        def one(params, cache_i, tok_i, pos_i):
            cache_i = jax.tree.map(lambda a: jnp.expand_dims(a, 1), cache_i)
            logits, nc = model_decode_step(cfg, params, cache_i,
                                           tok_i[None], pos_i)
            return (jnp.argmax(logits[0]).astype(jnp.int32),
                    jax.tree.map(lambda a: jnp.squeeze(a, axis=1), nc))

        def chunk(params, cache, tok, pos, forced, forced_len, remaining):
            def body(carry, forced_j):
                cache, tok, pos, rem, fl = carry
                nxt, cache = jax.vmap(one, in_axes=(None, 1, 0, 0),
                                      out_axes=(0, 1))(params, cache, tok,
                                                       pos)
                done = rem <= 0             # finished before this sub-step
                is_forced = (~done) & (fl > 0)
                emitting = (~done) & (fl <= 0)
                rem = jnp.where(emitting, rem - 1, rem)
                if eos is not None:
                    rem = jnp.where(emitting & (nxt == jnp.int32(eos)),
                                    0, rem)
                finishing = emitting & (rem <= 0)
                tok = jnp.where(is_forced, forced_j,
                                jnp.where(emitting & ~finishing, nxt, tok))
                pos = jnp.where(done, pos, pos + 1)
                fl = jnp.where(is_forced, fl - 1, fl)
                return (cache, tok, pos, rem, fl), nxt

            (cache, tok, pos, _, _), mat = jax.lax.scan(
                body, (cache, tok, pos, remaining, forced_len), forced)
            return mat, cache, tok, pos

        return jax.jit(chunk, donate_argnums=(1,))

    def _build_prefill(self, n: int):
        cfg, budget = self.cfg, self.seq_budget

        def prefill_insert(params, cache, toks, slot):
            logits, one = model_prefill(cfg, params, {"tokens": toks}, budget)
            cache = jax.tree.map(
                lambda full, c1: jax.lax.dynamic_update_slice_in_dim(
                    full, c1.astype(full.dtype), slot, axis=1), cache, one)
            return jnp.argmax(logits[0]).astype(jnp.int32), cache

        del n   # the compile is keyed by toks.shape; n only names the cache
        return jax.jit(prefill_insert, donate_argnums=(1,))

    def _build_prefill_batch(self):
        """Batched prefill-insert: (c, n) same-bucket token rows prefill as
        one batch and land in the cache through a traced slot-index vector
        (``full.at[:, idx].set`` — the vectorized form of the single-insert
        ``dynamic_update_slice`` over the slot axis).  Pad rows duplicate
        row 0 and write row 0's lane the identical values, so duplicate
        scatter indices are order-free."""
        cfg, budget = self.cfg, self.seq_budget

        def prefill_insert_many(params, cache, toks, idx):
            logits, many = model_prefill(cfg, params, {"tokens": toks},
                                         budget)
            cache = jax.tree.map(
                lambda full, cc: full.at[:, idx].set(cc.astype(full.dtype)),
                cache, many)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        return jax.jit(prefill_insert_many, donate_argnums=(1,))

    def reset(self) -> None:
        """Drop all in-flight requests and re-zero the cache/positions while
        keeping every compiled program (shapes are unchanged, so the jit
        caches stay warm — a server restart without the recompile)."""
        self.cache = model_init_cache(self.cfg, self.params, self.slots,
                                      self.seq_budget)
        self.tok[:] = 0
        self.pos[:] = 0
        self.tasks = [None] * self.slots
        self.completed = []

    # ----------------------------------------------------------- occupancy ---
    def free_slots(self) -> list:
        return [i for i, t in enumerate(self.tasks) if t is None]

    @property
    def n_active(self) -> int:
        return self.slots - len(self.free_slots())

    def pop_completed(self) -> list:
        out, self.completed = self.completed, []
        return out

    def prefill_len(self, prompt_len: int) -> int:
        return bucket_of(prompt_len, self.buckets)

    # -------------------------------------------------------------- insert ---
    def _check_request(self, req: Request) -> None:
        S = req.prompt_len
        if S < 1:
            raise ValueError(f"request {req.id}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.id}: max_new_tokens must be >= 1")
        if S + req.max_new_tokens > self.seq_budget:
            raise ValueError(
                f"request {req.id}: prompt ({S}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds seq_budget="
                f"{self.seq_budget}; the ring buffer would wrap and drop "
                "context")

    def _admit_task(self, req: Request, slot: int, n: int, first: int,
                    now: float) -> None:
        task = _SlotTask(req=req, pending=list(req.tokens[n:]),
                         admitted_at=float(now))
        self.tasks[slot] = task
        self.pos[slot] = n
        if task.pending:
            # the prefix's next-token prediction is a known prompt token:
            # discard the argmax, force the tail through the decode step
            self.tok[slot] = task.pending.pop(0)
        else:
            self._emit(slot, int(first), now)   # first generated token

    def insert(self, req: Request, now: float = 0.0) -> int:
        """Claim a free slot for ``req``: one compiled prefill of the bucket
        prefix, cache written into the slot, prompt tail queued as forced
        tokens for the shared decode step.  Returns the slot index."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot; admit at most free_slots()")
        self._check_request(req)
        slot = free[0]
        n = self.prefill_len(req.prompt_len)
        fn = self._prefill_fns.get(n)
        if fn is None:
            fn = self._prefill_fns[n] = self._build_prefill(n)
        with obs.span("serve.prefill", "serve", req=req.id, bucket=n,
                      slot=slot):
            toks = jnp.asarray(np.asarray(req.tokens[:n], np.int32)[None])
            first, self.cache = fn(self.params, self.cache, toks, slot)
        self.n_inserts += 1
        self.n_prefill_shots += 1
        reg = obs.current_registry()
        if reg is not None:
            reg.counter("serve.inserts").inc()
            reg.histogram("serve.prefill_batch_size").observe(1)
        self._admit_task(req, slot, n, int(first), now)
        return slot

    def batch_class(self, m: int) -> int:
        """The padded row count batched prefill compiles for ``m`` requests:
        the smallest power of two >= m, capped at ``slots`` — so the jit
        cache holds one program per (bucket, class), not per exact m."""
        c = 1
        while c < m:
            c *= 2
        return min(c, self.slots)

    def insert_batch(self, reqs: Sequence[Request],
                     now: float = 0.0) -> list:
        """Admit up to ``slots`` same-bucket requests in **one** compiled
        shot: their bucket prefixes prefill as a single (m, n) batch and
        the per-request caches land through a traced slot-index vector, so
        admission cost is one dispatch per group instead of one per
        request.  Token-identical to inserting each request alone.
        Returns the claimed slot indices, one per request, in order."""
        reqs = list(reqs)
        if not reqs:
            return []
        free = self.free_slots()
        if len(reqs) > len(free):
            raise RuntimeError(
                f"{len(reqs)} requests for {len(free)} free slots; "
                "admit at most free_slots()")
        ns = set()
        for req in reqs:
            self._check_request(req)
            ns.add(self.prefill_len(req.prompt_len))
        if len(ns) != 1:
            raise ValueError(
                "insert_batch needs same-bucket requests (one compiled "
                f"prefill length per shot); got buckets {sorted(ns)} — "
                "group with AdmissionQueue.admit(..., group=True)")
        n = ns.pop()
        m = len(reqs)
        c = self.batch_class(m)
        claimed = free[:m]
        toks = np.zeros((c, n), np.int32)
        idx = np.zeros((c,), np.int32)
        for row, (req, slot) in enumerate(zip(reqs, claimed)):
            toks[row] = np.asarray(req.tokens[:n], np.int32)
            idx[row] = slot
        toks[m:] = toks[0]          # pad rows duplicate row 0: they write
        idx[m:] = idx[0]            # row 0's lane the identical values
        fn = self._prefill_batch_fns.get((n, c))
        if fn is None:
            fn = self._prefill_batch_fns[(n, c)] = self._build_prefill_batch()
        with obs.span("serve.prefill", "serve", bucket=n, batch=m,
                      cls=c, slots=list(map(int, claimed))):
            firsts, self.cache = fn(self.params, self.cache,
                                    jnp.asarray(toks), jnp.asarray(idx))
            firsts = np.asarray(firsts)
        self.n_inserts += m
        self.n_prefill_shots += 1
        reg = obs.current_registry()
        if reg is not None:
            reg.counter("serve.inserts").inc(m)
            reg.histogram("serve.prefill_batch_size").observe(m)
        for row, (req, slot) in enumerate(zip(reqs, claimed)):
            self._admit_task(req, slot, n, int(firsts[row]), now)
        return claimed

    # ---------------------------------------------------------------- step ---
    def step(self, now: float = 0.0, decode_chunk: int = 1,
             step_dt: float = 0.0) -> list:
        """Decode for every slot (free lanes compute garbage that nothing
        reads).  ``decode_chunk=d`` folds d steps into one compiled scan
        with a single host sync; mid-chunk finishers are stamped at their
        true virtual sub-step time ``now + j * step_dt``.  Each d keys its
        own jit entry, so toggling chunk sizes never recompiles.  Returns
        the requests that finished."""
        if self.n_active == 0:
            return []
        d = int(decode_chunk)
        if d < 1:
            raise ValueError(f"decode_chunk must be >= 1, got {decode_chunk}")
        if d > 1:
            return self._step_chunk(now, d, float(step_dt))
        with obs.span("serve.decode", "serve", active=self.n_active, chunk=1):
            nxt, self.cache = self._step_fn(self.params, self.cache,
                                            self.tok, self.pos)
            nxt = np.asarray(nxt)       # the per-step host sync: (N,) tokens
        self.n_steps += 1
        self.n_dispatches += 1
        reg = obs.current_registry()
        if reg is not None:
            reg.counter("serve.decode_steps").inc()
            reg.gauge("serve.active_slots").set(self.n_active)
        done_before = len(self.completed)
        for i, task in enumerate(self.tasks):
            if task is None:
                continue
            self.pos[i] += 1
            if task.pending:
                # still consuming the prompt tail: the model's prediction is
                # superseded by the known next prompt token
                self.tok[i] = task.pending.pop(0)
            else:
                self._emit(i, int(nxt[i]), now)
        return self.completed[done_before:]

    def _step_chunk(self, now: float, d: int, step_dt: float) -> list:
        """d fused decode steps: one dispatch, one (d, N) token sync, then
        a host replay of the per-step bookkeeping the d=1 loop would have
        done — same emissions, same finish order, timestamps at the true
        virtual sub-step.  ``n_steps`` advances by the number of sub-steps
        that still had an active lane (exactly the steps the per-token loop
        would have executed); trailing garbage sub-steps cost only device
        time, already amortized into the chunk's single dispatch."""
        N = self.slots
        forced = np.zeros((d, N), np.int32)
        forced_len = np.zeros((N,), np.int32)
        remaining = np.zeros((N,), np.int32)
        for i, task in enumerate(self.tasks):
            if task is None:
                continue
            tail = task.pending[:d]
            forced[:len(tail), i] = tail
            forced_len[i] = len(tail)
            remaining[i] = task.req.max_new_tokens - len(task.generated)
        fn = self._chunk_fns.get(d)
        if fn is None:
            fn = self._chunk_fns[d] = self._build_chunk(d)
        with obs.span("serve.decode", "serve", active=self.n_active, chunk=d):
            mat, self.cache, tok, pos = fn(
                self.params, self.cache, self.tok, self.pos,
                jnp.asarray(forced), jnp.asarray(forced_len),
                jnp.asarray(remaining))
            mat = np.asarray(mat)       # the chunk's one host sync
            # host copies: later bookkeeping mutates these in place
            tok, pos = np.array(tok, np.int32), np.array(pos, np.int32)
        self.n_dispatches += 1
        done_before = len(self.completed)
        used = 0
        for j in range(d):
            if all(t is None for t in self.tasks):
                break                   # the d=1 loop would have stopped
            used += 1
            t_j = now + j * step_dt     # true virtual time of sub-step j
            for i, task in enumerate(self.tasks):
                if task is None:
                    continue
                if task.pending:
                    task.pending.pop(0)     # forced: prediction superseded
                else:
                    self._emit(i, int(mat[j, i]), t_j)
        # the device chained tok/pos through the same masking the replay
        # just applied (finished lanes frozen), so these ARE the d=1 state
        self.tok, self.pos = tok, pos
        self.n_steps += used
        reg = obs.current_registry()
        if reg is not None:
            reg.counter("serve.decode_steps").inc(used)
            reg.counter("serve.decode_chunks").inc()
            reg.gauge("serve.active_slots").set(self.n_active)
        return self.completed[done_before:]

    def _emit(self, slot: int, token: int, now: float) -> None:
        """Record one generated token for ``slot``; evict on completion
        (host bookkeeping only — no device work, no recompile)."""
        task = self.tasks[slot]
        if task.first_token_at is None:
            task.first_token_at = float(now)
            reg = obs.current_registry()
            if reg is not None:
                # admit -> first token, in the caller's clock (virtual or
                # wall) — the serving-latency histogram the bench reports
                reg.histogram("serve.admit_to_first_token_s").observe(
                    task.first_token_at - task.admitted_at)
        task.generated.append(token)
        done = (len(task.generated) >= task.req.max_new_tokens
                or (self.eos_id is not None and token == self.eos_id))
        if done:
            self.completed.append(Response(
                id=task.req.id, prompt_len=task.req.prompt_len,
                tokens=tuple(task.generated), weights_version=self.version,
                arrival=task.req.arrival, admitted_at=task.admitted_at,
                first_token_at=task.first_token_at, finished_at=float(now)))
            self.tasks[slot] = None
        else:
            self.tok[slot] = token

    # ---------------------------------------------------------------- swap ---
    def swap_weights(self, new_params, version: Optional[int] = None) -> None:
        """Hot-swap the serving weights.  The pytree must match the current
        params exactly (structure, shapes, dtypes — mismatches are named);
        the old buffers are donated, so the swap neither recompiles the
        decode/prefill programs nor doubles resident weight memory beyond
        the unavoidable old+incoming overlap.  ``step`` syncs before it
        returns, so a swap always lands at a decode-chunk boundary: every
        token inside one fused chunk comes from a single weights version,
        and the version stamped on a Response is exactly the version its
        chunks decoded under."""
        assert_tree_compatible(self.params, new_params,
                               what="hot-swapped serving weights")
        if not hasattr(self, "_swap_fn"):
            # old (donated) -> freed or aliased as the landing buffers for
            # the incoming values; `new` is NOT donated, so a trainer handing
            # us views into its live state keeps its buffers intact
            self._swap_fn = jax.jit(
                lambda old, new: jax.tree.map(
                    lambda o, n: n.astype(o.dtype), old, new),
                donate_argnums=(0,))
        with obs.span("serve.swap", "swap",
                      version=version if version is not None
                      else self.version + 1):
            self.params = self._swap_fn(self.params, new_params)
        self.version = int(version) if version is not None \
            else self.version + 1
        self.n_swaps += 1
        reg = obs.current_registry()
        if reg is not None:
            reg.counter("serve.swaps").inc()

    # ----------------------------------------------------------- telemetry ---
    def compile_counts(self) -> dict:
        """Compiled-program counts per entry point — the no-recompile pin:
        after warmup ``step`` stays at 1, each ``decode_chunk`` size at 1,
        ``prefill`` at one per bucket used (the bucket-1 fallback keeps the
        set inside the bucket set), and ``prefill_batch`` at one per
        (bucket, batch-size-class), no matter how many requests churn
        through."""
        return {"step": jit_cache_size(self._step_fn),
                "decode_chunk": {d: jit_cache_size(fn)
                                 for d, fn in sorted(self._chunk_fns.items())},
                "prefill": {n: jit_cache_size(fn)
                            for n, fn in sorted(self._prefill_fns.items())},
                "prefill_batch": {
                    f"{n}x{c}": jit_cache_size(fn)
                    for (n, c), fn in sorted(self._prefill_batch_fns.items())}}

    def stats(self) -> dict:
        return {"slots": self.slots, "active": self.n_active,
                "steps": self.n_steps, "dispatches": self.n_dispatches,
                "inserts": self.n_inserts,
                "prefill_shots": self.n_prefill_shots,
                "swaps": self.n_swaps, "version": self.version,
                "compiles": self.compile_counts()}
