"""Slot-based continuous-batching inference engine.

`ServeEngine` holds a fixed-capacity decode batch — ``slots`` lanes of the
existing ring-buffer KV / O(1) SSM decode cache (`models.api`) — and drives
it with exactly two kinds of compiled program:

  * one **decode step** for all slots at once: per-slot token and position,
    vmapped over the slot axis of the batched cache, greedy argmax on
    device.  The slot count is static and free slots simply compute garbage
    lanes (the same static-shape discipline as `BatchCtx.active_budget`),
    so admitting and evicting requests never recompiles — one compile
    serves the server's whole lifetime, pinned by tests/test_serve.py.
  * one **prefill-insert** per prompt-length bucket: prefill the largest
    bucket-length *prefix* of the prompt in a single full-sequence shot,
    write the resulting one-request cache into the claimed slot
    (``dynamic_update_slice`` along the slot axis, slot index traced), and
    feed the short prompt tail through the normal decode step as forced
    tokens.  No prompt padding ever enters the model, so a request decodes
    **token-identically** to serving it alone; the bucket set only bounds
    how many prefill programs get compiled.

Per-slot bookkeeping (prompt tail, generated tokens, timestamps) is plain
host Python: the device work per step is one dispatch returning the (N,)
argmax tokens — the host sync serving must pay anyway to emit tokens.

Weights are swapped live via ``swap_weights`` (see `repro.serve.swap` for
the `FedEngine` hook): treedefs/shapes must match the current serving
params (checked, mismatches named), the old buffers are donated to the
swap jit so the new weights land in their storage, and a version counter
is stamped onto every `Response` so callers can tell which federated
round's distilled model produced their tokens.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import assert_tree_compatible
from ..models.api import model_decode_step, model_init_cache, model_prefill
from ..models.base import ModelConfig
from ..obs import trace as obs
from ..obs.jit_watch import jit_cache_size  # canonical impl; re-exported
from .queue import Request, Response, bucket_of

DEFAULT_BUCKETS = (16, 32, 64, 128)


@dataclass
class _SlotTask:
    """Host-side state of one occupied slot."""
    req: Request
    pending: list                       # prompt-tail tokens not yet fed
    generated: list = field(default_factory=list)
    admitted_at: float = 0.0
    first_token_at: Optional[float] = None


class ServeEngine:
    """Continuous-batching greedy decoder over a fixed slot budget.

    ``seq_budget`` caps prompt + generation per request (it sizes the
    ring-buffer KV cache, so staying under it keeps full-context exactness).
    ``buckets`` are the compiled prefill lengths (see module docstring);
    prompts shorter than every bucket prefill at their exact length, each
    distinct short length costing one extra compile.

    Token-only architectures (dense / moe / ssm / hybrid); the audio and
    vlm stubs need modality inputs a prompt doesn't carry.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 seq_budget: int = 128,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 eos_id: Optional[int] = None, version: int = 0):
        if cfg.arch_type in ("vlm", "audio"):
            raise NotImplementedError(
                f"ServeEngine serves token-only archs; {cfg.arch_type!r} "
                "needs modality inputs per request")
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        self.cfg = cfg
        self.params = params
        self.slots = int(slots)
        self.seq_budget = int(seq_budget)
        self.buckets = tuple(sorted(b for b in buckets
                                    if b <= self.seq_budget))
        self.eos_id = eos_id
        self.version = int(version)

        self.cache = model_init_cache(cfg, params, self.slots, self.seq_budget)
        self.tok = np.zeros((self.slots,), np.int32)
        self.pos = np.zeros((self.slots,), np.int32)
        self.tasks: list = [None] * self.slots
        self.completed: list = []       # drained by pop_completed()
        self.n_steps = 0
        self.n_inserts = 0
        self.n_swaps = 0

        self._step_fn = self._build_step()
        self._prefill_fns: dict = {}    # prefill length -> jitted insert

    # -------------------------------------------------------- compiled fns ---
    def _build_step(self):
        cfg = self.cfg

        def one(params, cache_i, tok_i, pos_i):
            cache_i = jax.tree.map(lambda a: jnp.expand_dims(a, 1), cache_i)
            logits, nc = model_decode_step(cfg, params, cache_i,
                                           tok_i[None], pos_i)
            return (jnp.argmax(logits[0]).astype(jnp.int32),
                    jax.tree.map(lambda a: jnp.squeeze(a, axis=1), nc))

        def step(params, cache, tok, pos):
            # vmap over the slot axis (axis 1 of every cache leaf: leaves are
            # (n_blocks, slots, ...)); each lane sees its own position, so
            # slots at different depths decode in the same dispatch
            return jax.vmap(one, in_axes=(None, 1, 0, 0),
                            out_axes=(0, 1))(params, cache, tok, pos)

        return jax.jit(step, donate_argnums=(1,))

    def _build_prefill(self, n: int):
        cfg, budget = self.cfg, self.seq_budget

        def prefill_insert(params, cache, toks, slot):
            logits, one = model_prefill(cfg, params, {"tokens": toks}, budget)
            cache = jax.tree.map(
                lambda full, c1: jax.lax.dynamic_update_slice_in_dim(
                    full, c1.astype(full.dtype), slot, axis=1), cache, one)
            return jnp.argmax(logits[0]).astype(jnp.int32), cache

        del n   # the compile is keyed by toks.shape; n only names the cache
        return jax.jit(prefill_insert, donate_argnums=(1,))

    def reset(self) -> None:
        """Drop all in-flight requests and re-zero the cache/positions while
        keeping every compiled program (shapes are unchanged, so the jit
        caches stay warm — a server restart without the recompile)."""
        self.cache = model_init_cache(self.cfg, self.params, self.slots,
                                      self.seq_budget)
        self.tok[:] = 0
        self.pos[:] = 0
        self.tasks = [None] * self.slots
        self.completed = []

    # ----------------------------------------------------------- occupancy ---
    def free_slots(self) -> list:
        return [i for i, t in enumerate(self.tasks) if t is None]

    @property
    def n_active(self) -> int:
        return self.slots - len(self.free_slots())

    def pop_completed(self) -> list:
        out, self.completed = self.completed, []
        return out

    def prefill_len(self, prompt_len: int) -> int:
        return bucket_of(prompt_len, self.buckets)

    # -------------------------------------------------------------- insert ---
    def insert(self, req: Request, now: float = 0.0) -> int:
        """Claim a free slot for ``req``: one compiled prefill of the bucket
        prefix, cache written into the slot, prompt tail queued as forced
        tokens for the shared decode step.  Returns the slot index."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot; admit at most free_slots()")
        S = req.prompt_len
        if S < 1:
            raise ValueError(f"request {req.id}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.id}: max_new_tokens must be >= 1")
        if S + req.max_new_tokens > self.seq_budget:
            raise ValueError(
                f"request {req.id}: prompt ({S}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds seq_budget="
                f"{self.seq_budget}; the ring buffer would wrap and drop "
                "context")
        slot = free[0]
        n = self.prefill_len(S)
        fn = self._prefill_fns.get(n)
        if fn is None:
            fn = self._prefill_fns[n] = self._build_prefill(n)
        with obs.span("serve.prefill", "serve", req=req.id, bucket=n,
                      slot=slot):
            toks = jnp.asarray(np.asarray(req.tokens[:n], np.int32)[None])
            first, self.cache = fn(self.params, self.cache, toks, slot)
        self.n_inserts += 1
        reg = obs.current_registry()
        if reg is not None:
            reg.counter("serve.inserts").inc()

        task = _SlotTask(req=req, pending=list(req.tokens[n:]),
                         admitted_at=float(now))
        self.tasks[slot] = task
        self.pos[slot] = n
        if task.pending:
            # the prefix's next-token prediction is a known prompt token:
            # discard the argmax, force the tail through the decode step
            self.tok[slot] = task.pending.pop(0)
        else:
            a0 = int(first)             # first generated token
            self._emit(slot, a0, now)
        return slot

    # ---------------------------------------------------------------- step ---
    def step(self, now: float = 0.0) -> list:
        """One decode step for every slot (free lanes compute garbage that
        nothing reads).  Returns the requests that finished this step."""
        if self.n_active == 0:
            return []
        with obs.span("serve.decode", "serve", active=self.n_active):
            nxt, self.cache = self._step_fn(self.params, self.cache,
                                            self.tok, self.pos)
            nxt = np.asarray(nxt)       # the per-step host sync: (N,) tokens
        self.n_steps += 1
        reg = obs.current_registry()
        if reg is not None:
            reg.counter("serve.decode_steps").inc()
            reg.gauge("serve.active_slots").set(self.n_active)
        done_before = len(self.completed)
        for i, task in enumerate(self.tasks):
            if task is None:
                continue
            self.pos[i] += 1
            if task.pending:
                # still consuming the prompt tail: the model's prediction is
                # superseded by the known next prompt token
                self.tok[i] = task.pending.pop(0)
            else:
                self._emit(i, int(nxt[i]), now)
        return self.completed[done_before:]

    def _emit(self, slot: int, token: int, now: float) -> None:
        """Record one generated token for ``slot``; evict on completion
        (host bookkeeping only — no device work, no recompile)."""
        task = self.tasks[slot]
        if task.first_token_at is None:
            task.first_token_at = float(now)
            reg = obs.current_registry()
            if reg is not None:
                # admit -> first token, in the caller's clock (virtual or
                # wall) — the serving-latency histogram the bench reports
                reg.histogram("serve.admit_to_first_token_s").observe(
                    task.first_token_at - task.admitted_at)
        task.generated.append(token)
        done = (len(task.generated) >= task.req.max_new_tokens
                or (self.eos_id is not None and token == self.eos_id))
        if done:
            self.completed.append(Response(
                id=task.req.id, prompt_len=task.req.prompt_len,
                tokens=tuple(task.generated), weights_version=self.version,
                arrival=task.req.arrival, admitted_at=task.admitted_at,
                first_token_at=task.first_token_at, finished_at=float(now)))
            self.tasks[slot] = None
        else:
            self.tok[slot] = token

    # ---------------------------------------------------------------- swap ---
    def swap_weights(self, new_params, version: Optional[int] = None) -> None:
        """Hot-swap the serving weights.  The pytree must match the current
        params exactly (structure, shapes, dtypes — mismatches are named);
        the old buffers are donated, so the swap neither recompiles the
        decode/prefill programs nor doubles resident weight memory beyond
        the unavoidable old+incoming overlap."""
        assert_tree_compatible(self.params, new_params,
                               what="hot-swapped serving weights")
        if not hasattr(self, "_swap_fn"):
            # old (donated) -> freed or aliased as the landing buffers for
            # the incoming values; `new` is NOT donated, so a trainer handing
            # us views into its live state keeps its buffers intact
            self._swap_fn = jax.jit(
                lambda old, new: jax.tree.map(
                    lambda o, n: n.astype(o.dtype), old, new),
                donate_argnums=(0,))
        with obs.span("serve.swap", "swap",
                      version=version if version is not None
                      else self.version + 1):
            self.params = self._swap_fn(self.params, new_params)
        self.version = int(version) if version is not None \
            else self.version + 1
        self.n_swaps += 1
        reg = obs.current_registry()
        if reg is not None:
            reg.counter("serve.swaps").inc()

    # ----------------------------------------------------------- telemetry ---
    def compile_counts(self) -> dict:
        """Compiled-program counts per entry point — the no-recompile pin:
        after warmup ``step`` stays at 1 and ``prefill`` at one per bucket
        length used, no matter how many requests churn through."""
        return {"step": jit_cache_size(self._step_fn),
                "prefill": {n: jit_cache_size(fn)
                            for n, fn in sorted(self._prefill_fns.items())}}

    def stats(self) -> dict:
        return {"slots": self.slots, "active": self.n_active,
                "steps": self.n_steps, "inserts": self.n_inserts,
                "swaps": self.n_swaps, "version": self.version,
                "compiles": self.compile_counts()}
