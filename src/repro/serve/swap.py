"""Live weight hot-swap: `FedEngine` -> `ServeEngine`.

The federated trainer periodically produces a new distilled global model
(`algo.eval_params(state)` — for DS-FL the mean client model trained on the
shared distillation logits).  `attach` wires a `WeightSync` observer into
`FedEngine.on_chunk`, so at every ``chunk_rounds`` boundary the serving
engine's weights are swapped in place:

  * the incoming pytree is checked against the serving params
    (`assert_tree_compatible` — structure, shapes, dtypes; mismatches are
    named), so a trainer running a different config fails loudly instead of
    serving garbage;
  * treedefs match, so the swap hits the already-compiled decode/prefill
    programs' jit caches — no recompile (pinned in tests/test_serve.py);
  * the serving engine's old buffers are donated inside
    `ServeEngine.swap_weights`; the trainer's state is passed as a regular
    argument and stays intact (FedAvg's ``eval_params`` returns *views* of
    the live client stack);
  * responses emitted after the swap are stamped with
    ``weights_version = rounds_done``, so a client can tell which round's
    model produced its tokens;
  * swaps land only at decode-**chunk** boundaries, mirroring the
    `on_chunk` discipline on the training side: `ServeEngine.step` syncs
    its fused chunk before returning, so a swap can never interleave with
    an in-flight chunk — every token inside one chunk comes from a single
    weights version, and a mid-request swap at a chunk boundary is
    token-identical to the same swap between single steps (pinned in
    tests/test_serve.py).

`swap_from_checkpoint` is the offline variant: load a params pytree saved
with `repro.checkpoint.save_pytree` and hot-swap it into a running server.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax

from ..checkpoint import load_pytree
from ..obs import trace as obs
from .engine import ServeEngine


@dataclass
class WeightSync:
    """`FedEngine.on_chunk` observer that hot-swaps a `ServeEngine`.

    ``every``: swap at every ``every``-th completed round that on_chunk
    reports (on_chunk already fires only at chunk boundaries; this thins it
    further).  ``swap_log`` records ``(round, seconds)`` per swap — the
    measured swap latency `benchmarks.serve_bench` reports."""
    serve: ServeEngine
    algo: object                        # FedAlgorithm (eval_params provider)
    every: int = 1
    swap_log: list = field(default_factory=list)

    def __call__(self, rounds_done: int, state) -> None:
        if rounds_done % max(1, int(self.every)) != 0:
            return
        with obs.span("swap.sync", "swap", round=rounds_done) as sp:
            params, _ = self.algo.eval_params(state)
            t0 = time.perf_counter()
            self.serve.swap_weights(params, version=rounds_done)
            jax.block_until_ready(self.serve.params)
            dt = time.perf_counter() - t0
            # the decode-chunk boundary the swap landed at: every token of
            # a fused chunk decodes under one weights version
            sp.set(swap_s=dt, serve_steps=self.serve.n_steps)
        self.swap_log.append((int(rounds_done), dt))
        reg = obs.current_registry()
        if reg is not None:
            reg.histogram("swap.latency_s").observe(dt)

    @property
    def last_swap_s(self) -> Optional[float]:
        return self.swap_log[-1][1] if self.swap_log else None


def attach(fed_engine, serve_engine: ServeEngine, algo,
           every: int = 1) -> WeightSync:
    """Install a `WeightSync` as ``fed_engine.on_chunk`` and return it.
    ``algo`` is the algorithm instance the trainer runs (its ``eval_params``
    extracts the servable global model from the round state)."""
    sync = WeightSync(serve=serve_engine, algo=algo, every=every)
    fed_engine.on_chunk = sync
    return sync


def swap_from_checkpoint(serve_engine: ServeEngine, path: str,
                         version: Optional[int] = None) -> float:
    """Load a params pytree (`save_pytree` format) and hot-swap it into a
    running server; returns the measured swap latency in seconds."""
    params = load_pytree(path)
    t0 = time.perf_counter()
    serve_engine.swap_weights(params, version=version)
    jax.block_until_ready(serve_engine.params)
    return time.perf_counter() - t0
