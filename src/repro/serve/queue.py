"""Admission queue in front of `repro.serve.ServeEngine`.

Requests land here first; the queue buckets them by prompt length (the
bucket picks which compiled prefill serves the request — see
`ServeEngine.prefill_len`), holds them FIFO *within* each bucket, sheds
requests that overstay ``timeout`` or arrive while the backlog is at
``max_queue`` (overload protection: a bounded queue turns a latency
collapse into explicit, accounted shed), and stamps per-request latency
bookkeeping (arrival / admission / first token / finish) that the load
generator and `benchmarks.serve_bench` aggregate into p50/p99.

Everything here is host-side Python over small ints — no jax — so the
queue invariants are hypothesis-testable without a device
(tests/test_serve.py).
"""
from __future__ import annotations

import itertools
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

from ..obs import trace as obs


@dataclass(frozen=True)
class Request:
    """One generation request.  ``tokens`` is the prompt (host ints);
    ``arrival`` is the submitting clock's timestamp (virtual or wall —
    the queue never reads a clock itself, callers pass ``now``)."""
    id: int
    tokens: tuple
    max_new_tokens: int
    arrival: float = 0.0

    @property
    def prompt_len(self) -> int:
        return len(self.tokens)


@dataclass(frozen=True)
class Response:
    """A finished (or shed) request with its latency bookkeeping.
    ``weights_version`` is the serving-weight version counter stamped by
    `ServeEngine` — after a live hot-swap it tells which federated round's
    distilled model produced the tokens."""
    id: int
    prompt_len: int
    tokens: tuple                       # generated tokens (empty if shed)
    weights_version: int = -1
    arrival: float = 0.0
    admitted_at: float = -1.0
    first_token_at: float = -1.0
    finished_at: float = -1.0
    shed: bool = False

    @property
    def latency(self) -> float:
        """Full arrival-to-finish latency (the number p50/p99 report on)."""
        return self.finished_at - self.arrival

    @property
    def queue_delay(self) -> float:
        return self.admitted_at - self.arrival

    @property
    def queue_wait(self) -> float:
        """Time spent in the admission queue — admission for served
        requests, the shed moment for shed ones.  Unlike ``queue_delay``
        this is well-defined for every response, so shed requests' waiting
        time lands in the latency accounting instead of vanishing."""
        end = self.finished_at if self.shed else self.admitted_at
        return end - self.arrival

    @property
    def ttft(self) -> float:
        """Time to first generated token."""
        return self.first_token_at - self.arrival


def bucket_of(prompt_len: int, buckets: Sequence[int]) -> int:
    """The prefill bucket serving a prompt: the largest bucket <= the prompt
    length (the engine prefills that prefix in one compiled shot and feeds
    the short tail through the already-compiled decode step).  Prompts
    shorter than every bucket fall back to their exact length — each
    distinct short length costs one extra prefill compile."""
    fit = [b for b in buckets if b <= prompt_len]
    return max(fit) if fit else prompt_len


@dataclass
class AdmissionQueue:
    """Bounded, bucketed FIFO with timeout shedding.

    ``buckets`` must match the serving engine's (they name the compiled
    prefill lengths).  ``timeout``: a request still queued ``timeout``
    after arrival is shed at the next ``admit``/``shed_expired`` call;
    ``max_queue``: a submit beyond this backlog is shed immediately.
    ``None`` disables either policy.  Shed requests come back as
    `Response(shed=True)` so every submitted request is accounted exactly
    once (queue invariant, hypothesis-pinned)."""
    buckets: Sequence[int] = (16, 32, 64, 128)
    timeout: Optional[float] = None
    max_queue: Optional[int] = None

    def __post_init__(self):
        self.buckets = tuple(sorted(self.buckets))
        self._q: "OrderedDict[int, deque]" = OrderedDict()   # bucket -> FIFO
        self._ids = itertools.count()
        self.n_submitted = 0
        self.n_admitted = 0
        self.shed: list = []            # Response(shed=True), in shed order

    # ------------------------------------------------------------- intake ----
    def submit(self, tokens: Iterable[int], max_new_tokens: int,
               now: float = 0.0) -> Request:
        """Enqueue a request (or shed it on the spot if the backlog is at
        ``max_queue``).  Returns the Request either way; a shed submit is
        visible in ``self.shed``."""
        req = Request(id=next(self._ids), tokens=tuple(int(t) for t in tokens),
                      max_new_tokens=int(max_new_tokens), arrival=float(now))
        self.n_submitted += 1
        if self.max_queue is not None and len(self) >= self.max_queue:
            self.shed.append(self._shed_response(req, now))
            self._publish(shed=1)
            obs.instant("queue.shed", "queue", req=req.id, reason="backlog")
            return req
        b = bucket_of(req.prompt_len, self.buckets)
        self._q.setdefault(b, deque()).append(req)
        self._publish()
        return req

    # ---------------------------------------------------------- admission ----
    def admit(self, now: float, free_slots: int, group: bool = False) -> list:
        """Pop up to ``free_slots`` requests, oldest-arrival first across
        buckets (which preserves FIFO within every bucket), after shedding
        everything past ``timeout``.

        ``group=True`` is the batched-prefill mode: every returned request
        shares the bucket of the globally oldest queued request, popped
        FIFO from that bucket only — a group `ServeEngine.insert_batch`
        can admit in one compiled shot.  Other buckets wait for the next
        ``admit`` call, so per-bucket FIFO and oldest-bucket-first order
        both survive grouping (hypothesis-pinned)."""
        self.shed_expired(now)
        out = []
        bucket = None
        while len(out) < free_slots:
            req = self._pop_oldest(bucket)
            if req is None:
                break
            if group and bucket is None:
                bucket = bucket_of(req.prompt_len, self.buckets)
            self.n_admitted += 1
            out.append(req)
        if out:
            self._publish()
            reg = obs.current_registry()
            if reg is not None:
                for req in out:
                    reg.histogram("queue.wait_s").observe(now - req.arrival)
        return out

    def shed_expired(self, now: float) -> list:
        """Drop every queued request older than ``timeout``; returns (and
        records) their shed Responses."""
        if self.timeout is None:
            return []
        dropped = []
        for b, q in self._q.items():
            keep = deque()
            for req in q:
                if now - req.arrival > self.timeout:
                    dropped.append(self._shed_response(req, now))
                else:
                    keep.append(req)
            self._q[b] = keep
        self.shed.extend(dropped)
        if dropped:
            self._publish(shed=len(dropped))
            for r in dropped:
                obs.instant("queue.shed", "queue", req=r.id,
                            waited_s=r.queue_wait)
        return dropped

    def _pop_oldest(self, bucket: Optional[int] = None) -> Optional[Request]:
        """Oldest queued request — across buckets, or (grouped admission)
        from ``bucket`` only."""
        if bucket is not None:
            q = self._q.get(bucket)
            return q.popleft() if q else None
        best = None
        for b, q in self._q.items():
            if q and (best is None or q[0].arrival < self._q[best][0].arrival):
                best = b
        return self._q[best].popleft() if best is not None else None

    @staticmethod
    def _shed_response(req: Request, now: float) -> Response:
        # finished_at is the shed moment, so latency/queue_wait cover the
        # full time the request sat in the queue before being dropped
        return Response(id=req.id, prompt_len=req.prompt_len, tokens=(),
                        arrival=req.arrival, finished_at=float(now), shed=True)

    def _publish(self, shed: int = 0) -> None:
        reg = obs.current_registry()
        if reg is None:
            return
        reg.gauge("queue.depth").set(len(self))
        if shed:
            reg.counter("queue.shed").inc(shed)

    # ------------------------------------------------------------- state -----
    def __len__(self) -> int:
        return sum(len(q) for q in self._q.values())

    def pending(self) -> list:
        """Queued requests, oldest first (diagnostic view)."""
        return sorted((r for q in self._q.values() for r in q),
                      key=lambda r: (r.arrival, r.id))
