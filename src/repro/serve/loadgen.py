"""Deterministic open-loop load generator for `ServeEngine`.

Arrivals are open-loop (a Poisson process at ``rate`` requests per virtual
second, independent of server progress — the regime where queueing actually
builds) and everything is seeded and simulated in **virtual time**: the
clock advances by fixed per-operation costs (``prefill_cost`` per insert,
``step_cost`` per decode step) instead of reading a wall clock.  Two runs
with the same seed produce bit-identical schedules, latencies, and shed
sets on any machine — so `benchmarks.serve_bench` numbers are comparable
across hosts and CI can assert on them.  Wall-clock duration of the whole
run is measured separately (one perf_counter pair) purely for real
tokens/sec throughput.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..obs.metrics import percentile, percentiles
from .engine import ServeEngine
from .queue import AdmissionQueue


@dataclass(frozen=True)
class LoadSpec:
    """Workload shape: ``n_requests`` arrivals at ``rate`` req/s (virtual),
    prompt lengths and generation lengths drawn uniformly from the given
    inclusive ranges, token ids uniform over ``vocab``.  Fully determined
    by ``seed``."""
    n_requests: int = 32
    rate: float = 4.0
    prompt_len: tuple = (4, 48)
    max_new: tuple = (4, 16)
    vocab: int = 256
    seed: int = 0


def draw_arrivals(spec: LoadSpec) -> list:
    """The workload as ``(arrival_time, tokens, max_new)`` triples, arrival
    order.  Exponential inter-arrivals at ``spec.rate``."""
    rng = np.random.default_rng(spec.seed)
    gaps = rng.exponential(1.0 / spec.rate, size=spec.n_requests)
    arrivals = np.cumsum(gaps)
    out = []
    for t in arrivals:
        S = int(rng.integers(spec.prompt_len[0], spec.prompt_len[1] + 1))
        m = int(rng.integers(spec.max_new[0], spec.max_new[1] + 1))
        toks = tuple(int(x) for x in rng.integers(0, spec.vocab, size=S))
        out.append((float(t), toks, m))
    return out


def run_load(engine: ServeEngine, queue: AdmissionQueue, spec: LoadSpec, *,
             step_cost: float = 0.01, prefill_cost: float = 0.05,
             decode_chunk: int = 1, batch_insert: bool = False) -> dict:
    """Drive ``engine`` through the whole workload and aggregate the result.

    The virtual clock advances by ``prefill_cost`` per compiled prefill
    shot (one per request, or one per same-bucket group with
    ``batch_insert=True``) and ``step_cost`` per accounted decode step;
    when the server is idle it jumps to the next arrival.

    ``decode_chunk=d`` runs the fused d-step decode path: one dispatch and
    one host sync per chunk, mid-chunk finishers stamped at their true
    virtual sub-step, and the clock advanced by exactly the sub-steps the
    per-token loop would have executed.  ``batch_insert=True`` admits
    same-bucket groups (`AdmissionQueue.admit(group=True)`) through
    `ServeEngine.insert_batch`.  Both paths are token-identical to the
    defaults.  Returns the summary dict (see `summarize`) plus the raw
    ``responses`` list.
    """
    pending = draw_arrivals(spec)
    next_arrival = 0                    # index into pending
    now = 0.0
    responses = []
    wall0 = time.perf_counter()
    while True:
        while (next_arrival < len(pending)
               and pending[next_arrival][0] <= now):
            t, toks, m = pending[next_arrival]
            queue.submit(toks, m, now=t)
            next_arrival += 1
        if batch_insert:
            while True:
                reqs = queue.admit(now, len(engine.free_slots()), group=True)
                if not reqs:
                    break
                now += prefill_cost     # one compiled shot per group
                engine.insert_batch(reqs, now)
        else:
            for req in queue.admit(now, len(engine.free_slots())):
                now += prefill_cost
                engine.insert(req, now)
        if engine.n_active:
            steps_before = engine.n_steps
            now += step_cost            # sub-step 0 happens at this time
            engine.step(now, decode_chunk=decode_chunk, step_dt=step_cost)
            now += (engine.n_steps - steps_before - 1) * step_cost
            responses.extend(engine.pop_completed())
        elif next_arrival < len(pending):
            now = pending[next_arrival][0]   # idle: jump to the next arrival
        elif len(queue):                     # pragma: no cover - queue can
            now += step_cost                 # only be non-empty mid-flight
        else:
            break
    wall_s = time.perf_counter() - wall0
    responses.extend(engine.pop_completed())
    responses.extend(queue.shed)
    return summarize(responses, makespan=now, wall_s=wall_s,
                     queue=queue, engine=engine)


def summarize(responses, *, makespan: float, wall_s: float,
              queue: Optional[AdmissionQueue] = None,
              engine: Optional[ServeEngine] = None) -> dict:
    """p50/p90/p99 latency + time-to-first-token (virtual seconds),
    throughput (generated tokens per virtual second, and per wall second),
    and exact shed accounting.  Percentiles all come from the one shared
    implementation in `repro.obs.metrics`; an empty series (e.g. the shed
    percentiles of a run that shed nothing) reports ``None`` — JSON null —
    not a -1.0 sentinel, so downstream report code must guard for it.
    Shed requests' queue-wait time is accounted (``queue_wait_*`` spans
    served *and* shed responses, and ``shed_wait_*`` reports how long
    dropped requests sat before being shed) rather than silently vanishing
    from the latency picture."""
    done = [r for r in responses if not r.shed]
    shed = [r for r in responses if r.shed]
    n_tokens = sum(len(r.tokens) for r in done)

    def pcts(prefix, xs):
        return {f"{prefix}_{k}_s": v
                for k, v in percentiles(xs, empty=None).items()}

    out = {
        "completed": len(done),
        "shed": len(shed),
        "tokens": n_tokens,
        "makespan_virtual_s": makespan,
        "wall_s": wall_s,
        **pcts("latency", [r.latency for r in done]),
        **pcts("ttft", [r.ttft for r in done]),
        "queue_delay_p50_s": percentile([r.queue_delay for r in done], 50,
                                        empty=None),
        # every submitted request's time-in-queue, shed included — the
        # number that shows overload instead of hiding it in the shed bin
        **pcts("queue_wait", [r.queue_wait for r in responses]),
        **pcts("shed_wait", [r.queue_wait for r in shed]),
        "throughput_tok_per_virtual_s":
            n_tokens / makespan if makespan > 0 else 0.0,
        "throughput_tok_per_wall_s":
            n_tokens / wall_s if wall_s > 0 else 0.0,
        "responses": responses,
    }
    if queue is not None:
        out["n_submitted"] = queue.n_submitted
        out["n_admitted"] = queue.n_admitted
    if engine is not None:
        out["decode_steps"] = engine.n_steps
        out["decode_dispatches"] = engine.n_dispatches
        out["prefill_shots"] = engine.n_prefill_shots
        out["compiles"] = engine.compile_counts()
        out["weights_version"] = engine.version
    return out
