"""Continuous-batching inference with live weight hot-swap from the
federated trainer.  See ROADMAP.md "Serving" for the quickstart."""
from .engine import ServeEngine, jit_cache_size  # noqa
from .loadgen import LoadSpec, draw_arrivals, run_load, summarize  # noqa
from .queue import AdmissionQueue, Request, Response, bucket_of  # noqa
from .swap import WeightSync, attach, swap_from_checkpoint  # noqa
