"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def era_sharpen_ref(local_probs: jax.Array, temperature: float) -> jax.Array:
    """(K, N, C) client probs -> (N, C) sharpened global logit (Eq. 13)."""
    mean = jnp.mean(local_probs.astype(F32), axis=0)
    return jax.nn.softmax(mean / temperature, axis=-1)


def weighted_era_sharpen_ref(local_probs: jax.Array, weights: jax.Array,
                             temperature: float = 0.1,
                             sharpen: bool = True) -> jax.Array:
    """(K, N, C) x (K,) normalized weights -> (N, C) weighted mean, sharpened
    unless ``sharpen=False`` (the partial-participation Eq. 13)."""
    mean = jnp.einsum("k,knc->nc", weights.astype(F32),
                      local_probs.astype(F32))
    if not sharpen:
        return mean
    return jax.nn.softmax(mean / temperature, axis=-1)


def distill_loss_ref(student_logits: jax.Array, teacher_probs: jax.Array):
    """(N, V) -> per-row soft-target CE (N,) in fp32."""
    x = student_logits.astype(F32)
    m = jnp.max(x, axis=-1, keepdims=True)
    lz = m + jnp.log(jnp.sum(jnp.exp(x - m), axis=-1, keepdims=True))
    ls = x - lz
    return -jnp.sum(teacher_probs.astype(F32) * ls, axis=-1)


def distill_loss_grad_ref(student_logits, teacher_probs, g):
    """d(mean loss)/d logits given upstream scalar cotangent g."""
    x = student_logits.astype(F32)
    p = jax.nn.softmax(x, axis=-1)
    t = teacher_probs.astype(F32)
    tmass = jnp.sum(t, axis=-1, keepdims=True)
    n = x.shape[0]
    return (g / n) * (p * tmass - t)


def ssd_chunk_ref(x, dt, dA, Bm, Cm):
    """Within-chunk SSD block (the quadratic 'diagonal' term).

    x: (M, Q, H, P); dt/dA: (M, Q, H); Bm/Cm: (M, Q, G, N) -> y: (M, Q, H, P).
    """
    M, Q, H, P = x.shape
    G = Bm.shape[2]
    hpg = H // G
    cum = jnp.cumsum(dA.astype(F32), axis=1)                  # (M, Q, H)
    T = cum[:, :, None, :] - cum[:, None, :, :]               # (M, Q, Q, H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, :, :, None], jnp.exp(T), 0.0)
    Bh = jnp.repeat(Bm.astype(F32), hpg, axis=2)              # (M, Q, H, N)
    Ch = jnp.repeat(Cm.astype(F32), hpg, axis=2)
    scores = jnp.einsum("mqhn,mkhn->mqkh", Ch, Bh)            # (M, Q, Q, H)
    W = scores * L * dt.astype(F32)[:, None, :, :]            # dt over k axis
    return jnp.einsum("mqkh,mkhp->mqhp", W, x.astype(F32))
