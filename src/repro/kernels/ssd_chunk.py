"""Pallas kernel for the within-chunk ('diagonal') SSD block of Mamba2.

Per (chunk, head) tile: scores = (C B^T) * exp(segsum(dA)) * dt, y = scores x.
Tile shapes are MXU-aligned for the production configs (Q=256, N=128, P=64):
the (Q, N) x (N, Q) and (Q, Q) x (Q, P) matmuls hit the systolic array and
the whole working set (~Q*(2N+P+Q) fp32 ~ 0.6 MB) sits in VMEM.

Grid: (M, H) with M = batch * n_chunks; B/C blocks are indexed by the head's
group (GQA-style head->group map done in the BlockSpec index_map).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(x_ref, dt_ref, dA_ref, b_ref, c_ref, y_ref):
    # x: (1, Q, 1, P); dt/dA: (1, Q, 1); b/c: (1, Q, 1, N); y: (1, Q, 1, P)
    x = x_ref[0, :, 0, :].astype(F32)                         # (Q, P)
    dt = dt_ref[0, :, 0].astype(F32)                          # (Q,)
    dA = dA_ref[0, :, 0].astype(F32)
    B = b_ref[0, :, 0, :].astype(F32)                         # (Q, N)
    C = c_ref[0, :, 0, :].astype(F32)
    Q = x.shape[0]

    cum = jnp.cumsum(dA)
    T = cum[:, None] - cum[None, :]                           # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(T), 0.0)
    scores = jnp.dot(C, B.T, preferred_element_type=F32)      # (Q, Q)
    W = scores * L * dt[None, :]
    y_ref[0, :, 0, :] = jnp.dot(W, x, preferred_element_type=F32)


def ssd_chunk_pallas(x, dt, dA, Bm, Cm, interpret: bool = True):
    """x: (M, Q, H, P); dt/dA: (M, Q, H); Bm/Cm: (M, Q, G, N) -> (M, Q, H, P).
    fp32 output (cast by the caller)."""
    M, Q, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    grid = (M, H)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda m, h: (m, 0, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda m, h: (m, 0, h)),
            pl.BlockSpec((1, Q, 1), lambda m, h: (m, 0, h)),
            pl.BlockSpec((1, Q, 1, N), lambda m, h: (m, 0, h // hpg, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda m, h: (m, 0, h // hpg, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda m, h: (m, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((M, Q, H, P), F32),
        interpret=interpret,
    )(x, dt, dA, Bm, Cm)
