"""Jit-ready wrappers around the Pallas kernels (with custom VJPs where the
training path needs gradients).  ``INTERPRET = None`` means auto: interpret
mode on CPU (this container), the compiled kernel on TPU/GPU.  Set it to
True/False to force either mode globally, or pass ``interpret=`` per call
where the wrapper exposes it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .distill_loss import distill_loss_bwd_pallas, distill_loss_fwd_pallas
from .era_sharpen import (era_sharpen_pallas, resolve_interpret,
                          weighted_era_sharpen_pallas)
from .ssd_chunk import ssd_chunk_pallas

INTERPRET: bool | None = None     # None = auto (CPU -> interpret, else compiled)
F32 = jnp.float32


def _interp(flag: bool | None = None) -> bool:
    return resolve_interpret(INTERPRET if flag is None else flag)


# ------------------------------------------------------------ era_sharpen ----
def era_sharpen(local_probs: jax.Array, temperature: float = 0.1,
                interpret: bool | None = None) -> jax.Array:
    """(K, N, C) -> (N, C).  Teacher construction — not differentiated.
    Any N (the kernel pads the row axis to its block internally)."""
    return era_sharpen_pallas(jax.lax.stop_gradient(local_probs), temperature,
                              interpret=_interp(interpret))


def weighted_era_sharpen(local_probs: jax.Array, weights: jax.Array,
                         temperature: float = 0.1,
                         interpret: bool | None = None) -> jax.Array:
    """(K, N, C) x (K,) normalized weights -> (N, C): weighted mean + sharpen
    fused in one VMEM pass (the partial-participation teacher).  Zero-weight
    clients contribute exactly nothing.  Not differentiated."""
    return weighted_era_sharpen_pallas(
        jax.lax.stop_gradient(local_probs), jax.lax.stop_gradient(weights),
        temperature, interpret=_interp(interpret))


def weighted_mean(local_probs: jax.Array, weights: jax.Array,
                  interpret: bool | None = None) -> jax.Array:
    """(K, N, C) x (K,) normalized weights -> (N, C) weighted mean (the
    fused ``weighted_sa`` route: same kernel, softmax skipped)."""
    return weighted_era_sharpen_pallas(
        jax.lax.stop_gradient(local_probs), jax.lax.stop_gradient(weights),
        sharpen=False, interpret=_interp(interpret))


# ------------------------------------------------------------ distill loss ---
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def distill_loss_2d(z: jax.Array, t: jax.Array,
                    interpret: bool | None = None) -> jax.Array:
    losses, _ = distill_loss_fwd_pallas(z, t, interpret=_interp(interpret))
    return jnp.mean(losses)


def _dl_fwd(z, t, interpret):
    losses, logz = distill_loss_fwd_pallas(z, t, interpret=_interp(interpret))
    tmass = jnp.sum(t.astype(F32), axis=-1)
    return jnp.mean(losses), (z, t, logz, tmass)


def _dl_bwd(interpret, res, g):
    z, t, logz, tmass = res
    n = z.shape[0]
    gscale = jnp.reshape(g.astype(F32) / n, (1,))
    dz = distill_loss_bwd_pallas(z, t, logz, tmass, gscale,
                                 interpret=_interp(interpret))
    return dz, None


distill_loss_2d.defvjp(_dl_fwd, _dl_bwd)


def distill_loss(student_logits: jax.Array, teacher_probs: jax.Array,
                 mask=None, interpret: bool | None = None) -> jax.Array:
    """Arbitrary leading dims; mask unsupported on the kernel path (falls back
    to the reference implementation when given).  ``interpret=None`` = auto
    (CPU -> interpret, else the compiled kernel)."""
    if mask is not None:
        from ..core.losses import softmax_xent
        return softmax_xent(student_logits, teacher_probs, mask)
    V = student_logits.shape[-1]
    z = student_logits.reshape(-1, V)
    t = teacher_probs.reshape(-1, V)
    return distill_loss_2d(z, t, interpret)


# -------------------------------------------------------------- ssd chunk ----
def ssd_chunk(xr, dtr, dAr, Br, Cr, hpg: int) -> jax.Array:
    """Drop-in replacement for models.ssm._chunk_local:
    xr: (B, nc, Q, H, P) etc. -> (B, nc, Q, H, P) fp32."""
    B, nc, Q, H, P = xr.shape
    G, N = Br.shape[3], Br.shape[4]
    x2 = xr.reshape(B * nc, Q, H, P)
    dt2 = dtr.reshape(B * nc, Q, H)
    dA2 = dAr.reshape(B * nc, Q, H)
    B2 = Br.reshape(B * nc, Q, G, N)
    C2 = Cr.reshape(B * nc, Q, G, N)
    y = ssd_chunk_pallas(x2, dt2, dA2, B2, C2, interpret=_interp())
    return y.reshape(B, nc, Q, H, P)
