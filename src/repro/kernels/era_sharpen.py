"""Fused ERA kernels: (weighted) mean over the client axis + temperature
softmax.

On TPU this fuses the server's "4. Aggregation" (Eq. 13) into one VMEM pass:
the (K, bn, C) tile is averaged on the VPU and sharpened without writing the
intermediate mean back to HBM.  Row blocks tile N; the class dim stays whole
in VMEM (classification regime, C <= ~32k; the large-vocab LLM path uses the
top-k sparsified exchange instead — see core/aggregation.era_topk).

``weighted_era_sharpen_pallas`` is the partial-participation variant: the
(K, bn, C) tile is contracted against a (K,) weight vector — weighted mean
and sharpen in the same single VMEM pass, so the sim's ``weighted_sa``/
``weighted_era`` path no longer pays the two extra HBM passes of the
einsum + softmax fallback.  A zero-weight (absent/dropped) client
contributes exactly nothing: its tile rows are multiplied by an exact 0.0
before the sum, so even garbage logits from a masked-out client cannot
perturb the aggregate (asserted bitwise in tests/test_kernels.py).
``sharpen=False`` skips the softmax and returns the weighted mean itself —
the fused ``weighted_sa`` route.

Non-divisible row counts are handled by zero-padding the row axis up to the
block size: each row's mean+softmax is independent of every other row, so the
tail block's padding rows sharpen to garbage (a uniform distribution) and are
sliced off before returning — no cross-row contamination, no shape asserts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def resolve_interpret(flag: bool | None = None) -> bool:
    """Resolve an ``interpret`` flag: ``None`` means auto — interpret mode on
    CPU (where Mosaic cannot compile), the real compiled kernel elsewhere."""
    if flag is None:
        return jax.default_backend() == "cpu"
    return bool(flag)


def _kernel(probs_ref, out_ref, *, inv_temp: float, K: int):
    # probs_ref: (K, bn, C) f32 in VMEM; out_ref: (bn, C)
    p = probs_ref[...].astype(F32)
    mean = jnp.sum(p, axis=0) * (1.0 / K)                     # (bn, C)
    s = mean * inv_temp
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    out_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(out_ref.dtype)


def era_sharpen_pallas(local_probs: jax.Array, temperature: float,
                       block_n: int = 8,
                       interpret: bool | None = None) -> jax.Array:
    """local_probs: (K, N, C) -> (N, C) f32.  Any N (rows padded to the block
    size and sliced back); ``interpret=None`` = auto (CPU only)."""
    interpret = resolve_interpret(interpret)
    K, N, C = local_probs.shape
    block_n = min(block_n, N)
    pad = (-N) % block_n
    if pad:
        local_probs = jnp.pad(local_probs, ((0, 0), (0, pad), (0, 0)))
    n_pad = N + pad
    grid = (n_pad // block_n,)
    out = pl.pallas_call(
        functools.partial(_kernel, inv_temp=1.0 / temperature, K=K),
        grid=grid,
        in_specs=[pl.BlockSpec((K, block_n, C), lambda n: (0, n, 0))],
        out_specs=pl.BlockSpec((block_n, C), lambda n: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, C), F32),
        interpret=interpret,
    )(local_probs)
    return out[:N] if pad else out


def _weighted_kernel(w_ref, probs_ref, out_ref, *, inv_temp: float,
                     sharpen: bool):
    # w_ref: (K, 1) f32; probs_ref: (K, bn, C) in VMEM; out_ref: (bn, C).
    # The weighted sum runs on the VPU; an exact-zero weight annihilates its
    # client's rows (0.0 * p == 0.0 and x + 0.0 == x for finite p), so
    # absent clients contribute exactly nothing — no branch needed.
    p = probs_ref[...].astype(F32)
    w = w_ref[...].astype(F32)[:, :, None]                    # (K, 1, 1)
    acc = jnp.sum(p * w, axis=0)                              # (bn, C)
    if sharpen:
        s = acc * inv_temp
        m = jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s - m)
        acc = e / jnp.sum(e, axis=-1, keepdims=True)
    out_ref[...] = acc.astype(out_ref.dtype)


def weighted_era_sharpen_pallas(local_probs: jax.Array, weights: jax.Array,
                                temperature: float = 0.1, block_n: int = 8,
                                sharpen: bool = True,
                                interpret: bool | None = None) -> jax.Array:
    """local_probs: (K, N, C), weights: (K,) — already normalized by the
    caller (see ``core.aggregation._normalize_weights`` for the all-zero
    fallback) — -> (N, C) f32: ``softmax(sum_k w_k p_k / T)`` in one VMEM
    pass, or the weighted mean itself with ``sharpen=False``.  Any N (rows
    padded to the block and sliced back); ``interpret=None`` = auto."""
    interpret = resolve_interpret(interpret)
    K, N, C = local_probs.shape
    block_n = min(block_n, N)
    pad = (-N) % block_n
    if pad:
        local_probs = jnp.pad(local_probs, ((0, 0), (0, pad), (0, 0)))
    n_pad = N + pad
    w2d = weights.astype(F32).reshape(K, 1)
    out = pl.pallas_call(
        functools.partial(_weighted_kernel, inv_temp=1.0 / temperature,
                          sharpen=sharpen),
        grid=(n_pad // block_n,),
        in_specs=[pl.BlockSpec((K, 1), lambda n: (0, 0)),
                  pl.BlockSpec((K, block_n, C), lambda n: (0, n, 0))],
        out_specs=pl.BlockSpec((block_n, C), lambda n: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, C), F32),
        interpret=interpret,
    )(w2d, local_probs)
    return out[:N] if pad else out
