"""Fused ERA kernel: mean over the client axis + temperature softmax.

On TPU this fuses the server's "4. Aggregation" (Eq. 13) into one VMEM pass:
the (K, bn, C) tile is averaged on the VPU and sharpened without writing the
intermediate mean back to HBM.  Row blocks tile N; the class dim stays whole
in VMEM (classification regime, C <= ~32k; the large-vocab LLM path uses the
top-k sparsified exchange instead — see core/aggregation.era_topk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(probs_ref, out_ref, *, inv_temp: float, K: int):
    # probs_ref: (K, bn, C) f32 in VMEM; out_ref: (bn, C)
    p = probs_ref[...].astype(F32)
    mean = jnp.sum(p, axis=0) * (1.0 / K)                     # (bn, C)
    s = mean * inv_temp
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    out_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(out_ref.dtype)


def era_sharpen_pallas(local_probs: jax.Array, temperature: float,
                       block_n: int = 8, interpret: bool = True) -> jax.Array:
    """local_probs: (K, N, C) -> (N, C) f32."""
    K, N, C = local_probs.shape
    block_n = min(block_n, N)
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    return pl.pallas_call(
        functools.partial(_kernel, inv_temp=1.0 / temperature, K=K),
        grid=grid,
        in_specs=[pl.BlockSpec((K, block_n, C), lambda n: (0, n, 0))],
        out_specs=pl.BlockSpec((block_n, C), lambda n: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((N, C), F32),
        interpret=interpret,
    )(local_probs)
