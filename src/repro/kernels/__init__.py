from . import ops, ref  # noqa
