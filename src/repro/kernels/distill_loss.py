"""Vocab-blocked fused KD cross-entropy (the DS-FL "6. Distillation" loss).

CE(t || softmax(z)) per row, streaming over vocabulary tiles with an online
logsumexp — the full softmax is never materialized in HBM, which is the
memory hot-spot of distillation at LLM vocab sizes (bs x seq x 202k).

Grid: (N / bn, V / bv) with the vocab axis innermost; fp32 running
(max, sumexp, teacher-dot, teacher-mass) live in VMEM scratch across vocab
steps.  The backward kernel recomputes softmax from the saved per-row logZ.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .era_sharpen import resolve_interpret

F32 = jnp.float32
NEG = -1e30


def _fwd_kernel(z_ref, t_ref, loss_ref, lz_ref, m_s, l_s, td_s, tm_s, *,
                nv: int):
    v = pl.program_id(1)

    @pl.when(v == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)
        td_s[...] = jnp.zeros_like(td_s)
        tm_s[...] = jnp.zeros_like(tm_s)

    z = z_ref[...].astype(F32)                                # (bn, bv)
    t = t_ref[...].astype(F32)
    m_old = m_s[...]
    m_new = jnp.maximum(m_old, jnp.max(z, axis=-1))
    corr = jnp.exp(m_old - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(jnp.exp(z - m_new[:, None]), axis=-1)
    m_s[...] = m_new
    td_s[...] = td_s[...] + jnp.sum(t * z, axis=-1)
    tm_s[...] = tm_s[...] + jnp.sum(t, axis=-1)

    @pl.when(v == nv - 1)
    def _finish():
        logz = m_s[...] + jnp.log(l_s[...])
        loss_ref[...] = tm_s[...] * logz - td_s[...]
        lz_ref[...] = logz


def _bwd_kernel(z_ref, t_ref, lz_ref, tm_ref, gscale_ref, dz_ref):
    z = z_ref[...].astype(F32)
    t = t_ref[...].astype(F32)
    p = jnp.exp(z - lz_ref[...][:, None])
    g = gscale_ref[0]
    dz_ref[...] = (g * (p * tm_ref[...][:, None] - t)).astype(dz_ref.dtype)


def distill_loss_fwd_pallas(z: jax.Array, t: jax.Array, block_n: int = 256,
                            block_v: int = 2048,
                            interpret: bool | None = None):
    """z, t: (N, V) -> (per-row loss (N,), logZ (N,)).  ``interpret=None``
    = auto (the `kernels.ops` convention: interpret on CPU, compiled
    elsewhere — a hardcoded True would silently interpret on TPU/GPU)."""
    interpret = resolve_interpret(interpret)
    N, V = z.shape
    bn = min(block_n, N)
    bv = min(block_v, V)
    assert N % bn == 0 and V % bv == 0, (N, bn, V, bv)
    grid = (N // bn, V // bv)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, nv=V // bv),
        grid=grid,
        in_specs=[pl.BlockSpec((bn, bv), lambda n, v: (n, v)),
                  pl.BlockSpec((bn, bv), lambda n, v: (n, v))],
        out_specs=[pl.BlockSpec((bn,), lambda n, v: (n,)),
                   pl.BlockSpec((bn,), lambda n, v: (n,))],
        out_shape=[jax.ShapeDtypeStruct((N,), F32),
                   jax.ShapeDtypeStruct((N,), F32)],
        scratch_shapes=[pltpu.VMEM((bn,), F32) for _ in range(4)],
        interpret=interpret,
    )(z, t)


def distill_loss_bwd_pallas(z, t, logz, tmass, gscale, block_n: int = 256,
                            block_v: int = 2048,
                            interpret: bool | None = None):
    """Gradient wrt z: gscale * (softmax(z) * tmass - t). gscale: (1,) f32.
    ``interpret=None`` = auto (CPU -> interpret, else compiled)."""
    interpret = resolve_interpret(interpret)
    N, V = z.shape
    bn = min(block_n, N)
    bv = min(block_v, V)
    grid = (N // bn, V // bv)
    return pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bn, bv), lambda n, v: (n, v)),
                  pl.BlockSpec((bn, bv), lambda n, v: (n, v)),
                  pl.BlockSpec((bn,), lambda n, v: (n,)),
                  pl.BlockSpec((bn,), lambda n, v: (n,)),
                  pl.BlockSpec((1,), lambda n, v: (0,))],
        out_specs=pl.BlockSpec((bn, bv), lambda n, v: (n, v)),
        out_shape=jax.ShapeDtypeStruct((N, V), z.dtype),
        interpret=interpret,
    )(z, t, logz, tmass, gscale)
