"""`repro.obs` pins: zero-overhead-when-disabled parity, trace schema,
metrics invariants, compile accounting.

The load-bearing tests are the **parity** ones: an instrumented run
(tracer + metrics registry installed) must be *bitwise identical* to the
uninstrumented run on every path that carries instrumentation — the
engine's per-round loop, the fused ``chunk_rounds`` scan, and the
cohort-resident runner.  Instrumentation is host-side only (spans around
compiled calls, never inside them), so any divergence means a span leaked
into traced code.  The rest pins the trace JSONL schema + Perfetto export,
the histogram/percentile invariants (hypothesis where available, seeded
sweep always), and `JitCacheWatch` catching an injected recompile."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import DSFLAlgorithm
from repro.core.cohort import ClientStore
from repro.core.engine import FedEngine
from repro.core.protocol import DSFLConfig
from repro.data.pipeline import SyntheticProvider, build_image_task
from repro.models.smallnets import apply_tiny_mlp, init_tiny_mlp
from repro.obs import (Histogram, JitCacheWatch, MetricsRegistry,
                       RunProvenance, Tracer, engine_compile_counts,
                       install_registry, percentile, percentiles, span,
                       trace_to)
from repro.obs import trace as obs_trace
from repro.obs.jit_watch import jit_cache_size
from repro.obs.perfetto import read_trace, to_perfetto, validate
from repro.sim import ClientPopulation, CohortRunner, SyncScheduler

K = 6
HP = DSFLConfig(rounds=4, local_epochs=1, distill_epochs=1, batch_size=20,
                open_batch=40, aggregation="era")


@pytest.fixture(scope="module")
def task():
    return build_image_task(seed=0, K=K, n_private=240, n_open=80, n_test=40,
                            distribution="non_iid")


def _leaves(state):
    return [np.asarray(l) for l in jax.tree.leaves(state)]


def _assert_bitwise(a, b):
    la, lb = _leaves(a), _leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(x, y)


@pytest.fixture
def instrumented(tmp_path):
    """Install a tracer + registry for the duration of a test; yields the
    trace path.  Restores the disabled state afterwards."""
    path = str(tmp_path / "run.jsonl")
    with trace_to(path):
        prev = install_registry(MetricsRegistry())
        try:
            yield path
        finally:
            install_registry(prev)


# ------------------------------------------------------------------ parity ---
def test_engine_loop_bitwise_identical_under_tracing(task, tmp_path):
    """Per-round loop path: tracing + metrics publishing change nothing."""
    eng = FedEngine(DSFLAlgorithm(apply_tiny_mlp, HP))
    plain = eng.run(eng.init(init_tiny_mlp, task), task, rounds=4)
    plain_hist = list(eng.history)

    eng2 = FedEngine(DSFLAlgorithm(apply_tiny_mlp, HP))
    with trace_to(str(tmp_path / "t.jsonl")):
        prev = install_registry(MetricsRegistry())
        try:
            traced = eng2.run(eng2.init(init_tiny_mlp, task), task, rounds=4)
        finally:
            install_registry(prev)
    _assert_bitwise(plain, traced)
    assert list(eng2.history) == plain_hist


def test_engine_scan_bitwise_identical_under_tracing(task, tmp_path):
    """Fused ``chunk_rounds`` scan path: the span sits outside the compiled
    scan, so the chunk is the same program producing the same bits."""
    eng = FedEngine(DSFLAlgorithm(apply_tiny_mlp, HP))
    plain = eng.run(eng.init(init_tiny_mlp, task), task, rounds=4,
                    chunk_rounds=2, log_every=2)

    eng2 = FedEngine(DSFLAlgorithm(apply_tiny_mlp, HP))
    with trace_to(str(tmp_path / "t.jsonl")):
        prev = install_registry(MetricsRegistry())
        try:
            traced = eng2.run(eng2.init(init_tiny_mlp, task), task, rounds=4,
                              chunk_rounds=2, log_every=2)
        finally:
            install_registry(prev)
    _assert_bitwise(plain, traced)


def _cohort_run(seed_trace=None):
    hp = DSFLConfig(rounds=4, local_epochs=1, distill_epochs=1,
                    batch_size=10, open_batch=40, aggregation="era")
    algo = DSFLAlgorithm(apply_tiny_mlp, hp)
    eng = FedEngine(algo)
    prov = SyntheticProvider(seed=0, n_clients=K, n_per_client=10, n_open=40)
    sched = SyncScheduler(ClientPopulation.lognormal(0, K), fraction=0.5)
    rng0 = jax.random.PRNGKey(hp.seed)
    store = ClientStore(lambda ids: algo.init_cohort(rng0, init_tiny_mlp,
                                                     ids, K))
    runner = CohortRunner(engine=eng, scheduler=sched, provider=prov,
                          store=store, seed=0)
    state = runner.run(algo.init_server(rng0, init_tiny_mlp), rounds=4,
                       chunk_rounds=2)
    return state, store, list(runner.history)


def test_cohort_runner_bitwise_identical_under_tracing(tmp_path):
    """CohortRunner (plan/gather/scatter spans + store counters): same
    bits, same stored client rows, same history."""
    plain, store_p, hist_p = _cohort_run()
    with trace_to(str(tmp_path / "t.jsonl")):
        prev = install_registry(MetricsRegistry())
        try:
            traced, store_t, hist_t = _cohort_run()
        finally:
            install_registry(prev)
    _assert_bitwise(plain, traced)
    _assert_bitwise(store_p.gather(store_p.ids()),
                    store_t.gather(store_t.ids()))
    assert hist_p == hist_t


def test_disabled_path_is_shared_null_span():
    """The zero-overhead contract: with no tracer installed, ``span``
    returns one shared no-op object — no allocation, no timestamps."""
    assert obs_trace._TRACER is None, "a test leaked an installed tracer"
    s1, s2 = span("a", "engine", x=1), span("b")
    assert s1 is s2 is obs_trace._NULL_SPAN
    with s1 as s:
        s.set(anything=True)            # no-op, chainable


# ------------------------------------------------------------------ tracer ---
def test_tracer_schema_nesting_and_validation(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tracer = Tracer(path, provenance={"jax_version": jax.__version__})
    with tracer.span("outer", "app", depth=0):
        with tracer.span("inner", "engine"):
            pass
        tracer.instant("tick", "app", n=1)
    tracer.close()

    meta, records = read_trace(path)
    assert meta["clock"] == "perf_counter_ns"
    assert meta["provenance"]["jax_version"] == jax.__version__
    spans = [r for _, r in records if r["type"] == "span"]
    by_name = {r["name"]: r for r in spans}
    # inner closes (and is written) first; outer contains it in time
    assert [r["name"] for r in spans] == ["inner", "outer"]
    o, i = by_name["outer"], by_name["inner"]
    assert o["ts_us"] <= i["ts_us"]
    assert o["ts_us"] + o["dur_us"] >= i["ts_us"] + i["dur_us"]
    assert o["args"] == {"depth": 0}

    summary = validate(path, require_layers=("engine", "app"))
    assert summary["spans"] == 2 and summary["instants"] == 1


def test_trace_to_restores_previous_tracer(tmp_path):
    assert obs_trace._TRACER is None
    with trace_to(str(tmp_path / "a.jsonl")) as outer:
        assert obs_trace._TRACER is outer
        with trace_to(str(tmp_path / "b.jsonl")) as inner:
            assert obs_trace._TRACER is inner
        assert obs_trace._TRACER is outer
    assert obs_trace._TRACER is None


def test_span_set_attaches_args(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with trace_to(path):
        with span("measured", "wire") as sp:
            sp.set(up_bytes=10, down_bytes=20)
    _, records = read_trace(path)
    (rec,) = [r for _, r in records if r["type"] == "span"]
    assert rec["args"] == {"up_bytes": 10, "down_bytes": 20}


def test_perfetto_export_structure(tmp_path):
    src, dst = str(tmp_path / "t.jsonl"), str(tmp_path / "t.json")
    with trace_to(src):
        with span("work", "engine", r=1):
            pass
        obs_trace.instant("mark", "app")
    n = to_perfetto(src, dst)
    with open(dst) as f:
        out = json.load(f)
    evs = out["traceEvents"]
    assert n == len(evs)
    phs = {e["ph"] for e in evs}
    assert "X" in phs and "i" in phs and "M" in phs
    x = next(e for e in evs if e["ph"] == "X")
    assert x["name"] == "work" and x["cat"] == "engine" and x["dur"] >= 0
    assert "provenance" in out["otherData"]


def test_validate_rejects_malformed_trace(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"type": "meta", "clock": "perf_counter_ns", '
                   '"t0_ns": 0, "provenance": {"jax_version": "x"}}\n'
                   '{"type": "span", "name": "no-timestamps"}\n')
    with pytest.raises(ValueError):
        validate(str(bad))


# ----------------------------------------------------------------- metrics ---
def test_counter_gauge_histogram_snapshot():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(4)
    reg.gauge("g").set(2.5)
    for v in (0.001, 0.01, 0.01, 0.1):
        reg.histogram("h").observe(v)
    snap = reg.snapshot()
    assert snap["c"] == 5
    assert snap["g"] == 2.5
    h = snap["h"]
    assert h["count"] == 4 and h["min"] == 0.001 and h["max"] == 0.1
    assert h["p50"] <= h["p90"] <= h["p99"]


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_registry_to_json_with_provenance(tmp_path):
    path = str(tmp_path / "m.json")
    reg = MetricsRegistry()
    reg.counter("rounds").inc(3)
    reg.to_json(path, provenance={"git_sha": "abc"})
    with open(path) as f:
        out = json.load(f)
    assert out["provenance"] == {"git_sha": "abc"}
    assert out["metrics"]["rounds"] == 3


def test_exact_percentiles_are_the_one_implementation():
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert percentile(xs, 50) == 3.0
    assert percentile([], 50) == -1.0
    ps = percentiles(xs)
    assert set(ps) == {"p50", "p90", "p99"}
    assert ps["p50"] <= ps["p90"] <= ps["p99"]


def _check_histogram_invariants(xs):
    h = Histogram()
    for v in xs:
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == len(xs)
    assert snap["min"] == min(xs) and snap["max"] == max(xs)
    # bucket counts (plus overflow) partition the observations exactly
    assert sum(h.counts) + h.overflow == len(xs)
    qs = [h.percentile(q) for q in (1, 25, 50, 75, 90, 99)]
    for a, b in zip(qs, qs[1:]):        # monotone in q
        assert a <= b + 1e-12
    for v in qs:                        # estimates clamped to observed range
        assert min(xs) <= v <= max(xs)


def test_histogram_invariants_seeded_sweep():
    """Always-on version of the hypothesis property below (this container
    has no hypothesis): random magnitudes across the full bucket range,
    including out-of-range values, single observations, and ties."""
    rng = np.random.default_rng(0)
    for trial in range(50):
        n = int(rng.integers(1, 40))
        mags = rng.uniform(-8, 8, size=n)     # spans below/above the buckets
        xs = list(10.0 ** mags)
        if trial % 3 == 0:
            xs[: n // 2] = [xs[0]] * (n // 2)   # ties
        _check_histogram_invariants(xs)


def test_histogram_invariants_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st

    @given(st.lists(st.floats(min_value=1e-9, max_value=1e9,
                              allow_nan=False, allow_infinity=False),
                    min_size=1, max_size=60))
    @settings(deadline=None, max_examples=60,
              suppress_health_check=[HealthCheck.too_slow])
    def prop(xs):
        _check_histogram_invariants(xs)

    prop()


# --------------------------------------------------------------- jit_watch ---
def test_jit_cache_watch_catches_injected_recompile():
    """The regression pin: a wrapped jitted fn fed a *new input structure*
    is recorded (which fn, which treedef) and fails the no-new-compiles
    assertion; same-structure calls after mark() stay silent."""
    with JitCacheWatch() as watch:
        f = watch.wrap("f", jax.jit(lambda x: x * 2))
        f(jnp.ones(3))                   # first compile (during warmup)
        watch.mark()
        f(jnp.ones(3))                   # cache hit: still clean
        watch.assert_no_new_compiles()

        f(jnp.ones(5))                   # injected recompile: new shape
        new = watch.new_since_mark()
        assert any(r.kind == "cache" and r.name == "f" for r in new)
        with pytest.raises(AssertionError, match="f"):
            watch.assert_no_new_compiles()


def test_jit_watch_monitoring_sees_fresh_compile():
    """The jax.monitoring listener path: compiling a brand-new program
    fires an XLA compile event into every active watch."""
    with JitCacheWatch() as watch:
        salt = np.random.default_rng().integers(1 << 30)
        g = jax.jit(lambda x: x + float(salt))
        g(jnp.ones(2)).block_until_ready()
        assert watch.compiles() >= 1
        assert any(r.kind == "xla" for r in watch.records)


def test_engine_compile_counts_shape(task):
    eng = FedEngine(DSFLAlgorithm(apply_tiny_mlp, HP))
    eng.run(eng.init(init_tiny_mlp, task), task, rounds=1)
    counts = engine_compile_counts(eng)
    assert counts == eng.compile_counts()
    assert counts["round_signatures"] == 1
    assert counts["round_programs"] >= 1


def test_jit_cache_size_counts_programs():
    f = jax.jit(lambda x: x + 1)
    n0 = jit_cache_size(f)
    if n0 < 0:
        pytest.skip("jax without _cache_size")
    f(jnp.ones(2))
    f(jnp.ones(4))
    assert jit_cache_size(f) == n0 + 2


# -------------------------------------------------------------- provenance ---
def test_provenance_collects_this_environment():
    prov = RunProvenance.collect()
    assert prov.jax_version == jax.__version__
    assert prov.backend == jax.default_backend()
    assert isinstance(prov.x64, bool)
    d = prov.asdict()
    assert d["jax_version"] == jax.__version__
    # stamped into every trace header
    assert set(d) >= {"git_sha", "git_dirty", "jaxlib_version", "platform",
                      "python", "kernel_interpret", "n_devices"}


# ------------------------------------------------- instrumented serve smoke ---
def test_queue_shed_wait_is_accounted(instrumented):
    """Satellite pin: a shed request's queue-wait lands in the latency
    accounting (Response.queue_wait) and in the metrics, not dropped."""
    from repro.serve import AdmissionQueue
    from repro.serve.loadgen import summarize
    q = AdmissionQueue(buckets=(4,), timeout=1.0)
    q.submit((1, 2, 3, 4), 4, now=0.0)
    q.submit((1, 2, 3, 4), 4, now=0.5)
    dropped = q.shed_expired(now=2.0)    # both overstayed the 1s timeout
    assert [r.queue_wait for r in dropped] == [2.0, 1.5]
    rep = summarize(q.shed, makespan=2.0, wall_s=0.1)
    assert rep["shed"] == 2
    assert rep["shed_wait_p50_s"] == pytest.approx(1.75)
    assert rep["queue_wait_p99_s"] >= rep["queue_wait_p50_s"] > 0
    reg = obs_trace.current_registry()
    assert reg.snapshot()["queue.shed"] == 2
