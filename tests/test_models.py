"""Model zoo correctness: flash attention vs naive oracle, decode/prefill
equivalences, SSD vs naive recurrence, MoE invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.models import transformer as T
from repro.models.attention import flash_attention
from repro.models.base import ModelConfig
from repro.models.moe import capacity, moe_ffn, init_moe
from repro.models.ssm import ssd_chunked


def naive_attention(q, k, v, causal=True, window=None):
    B, Sq, H, hd = q.shape
    Kh = k.shape[2]
    G = H // Kh
    qg = q.reshape(B, Sq, Kh, G, hd)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k).astype(jnp.float32) * hd**-0.5
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqc,bckd->bkgqd", w.astype(v.dtype), v)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)


@given(st.integers(1, 3), st.sampled_from([8, 24, 33]),
       st.sampled_from([(4, 2), (4, 4), (6, 3)]),
       st.booleans(), st.sampled_from([None, 5, 16]),
       st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=25,
          suppress_health_check=[HealthCheck.too_slow])
def test_flash_attention_matches_naive(B, S, heads, causal, window, seed):
    H, Kh = heads
    hd = 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Kh, hd))
    v = jax.random.normal(ks[2], (B, S, Kh, hd))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_chunk=7, kv_chunk=5)
    exp = naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(out, exp, atol=1e-4, rtol=1e-4)


def naive_ssm_recurrence(x, dt, a_log, Bm, Cm):
    """Token-by-token SSD recurrence oracle."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    A = -jnp.exp(a_log)
    Bh = jnp.repeat(Bm, H // G, axis=2)
    Ch = jnp.repeat(Cm, H // G, axis=2)

    def step(state, t):
        dA = jnp.exp(dt[:, t] * A)                      # (B, H)
        st = state * dA[..., None, None] + \
            (dt[:, t, :, None] * x[:, t])[..., None] * Bh[:, t, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", st, Ch[:, t])
        return st, y

    state = jnp.zeros((Bsz, H, P, N))
    _, ys = jax.lax.scan(step, state, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_equals_naive_recurrence(rng, chunk):
    Bsz, S, H, P, G, N = 2, 16, 4, 8, 2, 8
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (Bsz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.3
    Bm = jax.random.normal(ks[3], (Bsz, S, G, N))
    Cm = jax.random.normal(jax.random.fold_in(rng, 9), (Bsz, S, G, N))
    y = ssd_chunked(x, dt, a_log, Bm, Cm, chunk)
    exp = naive_ssm_recurrence(x, dt, a_log, Bm, Cm)
    np.testing.assert_allclose(y, exp, atol=1e-4, rtol=1e-3)


def test_ssd_final_state_matches_recurrence(rng):
    Bsz, S, H, P, G, N = 1, 12, 2, 4, 1, 4
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (Bsz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.3
    Bm = jax.random.normal(ks[3], (Bsz, S, G, N))
    Cm = jax.random.normal(ks[4], (Bsz, S, G, N))
    _, final = ssd_chunked(x, dt, a_log, Bm, Cm, 4, return_state=True)
    # recompute naive final state
    A = -jnp.exp(a_log)
    Bh = jnp.repeat(Bm, H // G, axis=2)
    st = jnp.zeros((Bsz, H, P, N))
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A)
        st = st * dA[..., None, None] + \
            (dt[:, t, :, None] * x[:, t])[..., None] * Bh[:, t, :, None, :]
    np.testing.assert_allclose(final, st, atol=1e-4, rtol=1e-3)


# ------------------------------------------------------------------- MoE -----
def test_moe_capacity_formula():
    cfg = ModelConfig(name="m", arch_type="moe", n_layers=2, d_model=8,
                      n_heads=2, n_kv_heads=2, d_ff=16, vocab=32, n_experts=4,
                      top_k=2, moe_group_size=8, capacity_factor=1.0)
    assert capacity(cfg, 8) == 4          # 8 tokens * 2 / 4 experts


def test_moe_output_finite_and_router_grads_flow(rng):
    cfg = ModelConfig(name="m", arch_type="moe", n_layers=2, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab=32, n_experts=4,
                      top_k=2, moe_group_size=8, dtype="float32")
    p = init_moe(rng, cfg)
    x = jax.random.normal(rng, (2, 8, 16))
    out, aux = moe_ffn(p, cfg, x)
    assert out.shape == x.shape and bool(jnp.isfinite(out).all())
    g = jax.grad(lambda p_: moe_ffn(p_, cfg, x)[0].sum() +
                 moe_ffn(p_, cfg, x)[1])(p)
    assert bool(jnp.any(g["router"] != 0))


def test_moe_big_capacity_matches_dense_expert_mix(rng):
    """With capacity >> tokens and top_k = n_experts the MoE must equal the
    gate-weighted sum of every expert's dense FFN."""
    cfg = ModelConfig(name="m", arch_type="moe", n_layers=2, d_model=8,
                      n_heads=2, n_kv_heads=2, d_ff=16, vocab=32, n_experts=2,
                      top_k=2, moe_group_size=4, capacity_factor=4.0,
                      dtype="float32")
    p = init_moe(rng, cfg)
    x = jax.random.normal(rng, (1, 4, 8))
    out, _ = moe_ffn(p, cfg, x)
    gates = jax.nn.softmax(x.reshape(-1, 8) @ p["router"], -1)
    expert = lambda e: (jax.nn.silu(x.reshape(-1, 8) @ p["w_gate"][e])
                        * (x.reshape(-1, 8) @ p["w_up"][e])) @ p["w_down"][e]
    exp = (gates[:, 0:1] * expert(0) + gates[:, 1:2] * expert(1)).reshape(x.shape)
    np.testing.assert_allclose(out, exp, atol=1e-5)


# ----------------------------------------------------- decode equivalences ---
DENSE = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab=97,
                    dtype="float32")


@pytest.mark.parametrize("cfg", [
    DENSE,
    DENSE.replace(sliding_window=4),
    # capacity_factor=8: token-choice capacity drops differ between batched
    # and single-token execution by design; equivalence holds without drops
    DENSE.replace(arch_type="moe", n_experts=4, top_k=2, moe_group_size=8,
                  capacity_factor=8.0),
    ModelConfig(name="s", arch_type="ssm", n_layers=2, d_model=64, n_heads=0,
                n_kv_heads=0, d_ff=0, vocab=97, ssm_state=16, ssm_head_dim=16,
                ssm_chunk=8, dtype="float32"),
    ModelConfig(name="h", arch_type="hybrid", n_layers=4, d_model=64,
                n_heads=4, n_kv_heads=4, d_ff=128, vocab=97, ssm_state=16,
                ssm_head_dim=16, ssm_chunk=8, n_experts=4, top_k=2,
                moe_group_size=8, capacity_factor=8.0, dtype="float32",
                block_pattern=(("mamba", "mlp"), ("attn", "moe"))),
], ids=["dense", "windowed", "moe", "ssm", "hybrid"])
def test_decode_matches_full_forward(rng, cfg):
    params = T.init_lm(cfg, rng)
    toks = jax.random.randint(rng, (2, 12), 0, cfg.vocab)
    full, _ = T.lm_logits(cfg, params, toks, remat=False)
    cache = T.init_cache(cfg, 2, 12)
    for t in range(12):
        lg, cache = T.decode_step(cfg, params, cache, toks[:, t], jnp.int32(t))
    np.testing.assert_allclose(lg, full[:, -1], atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("cfg", [DENSE, DENSE.replace(sliding_window=4)],
                         ids=["dense", "windowed"])
def test_prefill_then_decode_matches_full(rng, cfg):
    params = T.init_lm(cfg, rng)
    toks = jax.random.randint(rng, (2, 16), 0, cfg.vocab)
    full, _ = T.lm_logits(cfg, params, toks, remat=False)
    last, cache = T.prefill(cfg, params, toks[:, :8], seq_len=16)
    np.testing.assert_allclose(last, full[:, 7], atol=2e-4, rtol=1e-3)
    lg, _ = T.decode_step(cfg, params, cache, toks[:, 8], jnp.int32(8))
    np.testing.assert_allclose(lg, full[:, 8], atol=2e-4, rtol=1e-3)


def test_vlm_patches_change_text_logits(rng):
    cfg = DENSE.replace(arch_type="vlm", n_patches=4)
    params = T.init_lm(cfg, rng)
    toks = jax.random.randint(rng, (2, 8), 0, cfg.vocab)
    pe1 = jax.random.normal(rng, (2, 4, 64))
    pe2 = pe1 + 1.0
    l1, _ = T.lm_logits(cfg, params, toks, pe1, remat=False)
    l2, _ = T.lm_logits(cfg, params, toks, pe2, remat=False)
    assert l1.shape == (2, 8, 97)
    assert not np.allclose(l1, l2)


def test_remat_matches_no_remat(rng):
    params = T.init_lm(DENSE, rng)
    toks = jax.random.randint(rng, (2, 12), 0, 97)
    a, _ = T.lm_logits(DENSE, params, toks, remat=True)
    b, _ = T.lm_logits(DENSE, params, toks, remat=False)
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_head_and_vocab_padding_preserve_numerics(rng):
    """pad_heads/pad_vocab (§Perf TP-divisibility optimization) must be
    numerics-preserving: padded model with real weights embedded == original."""
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=5, n_kv_heads=5, d_ff=128, vocab=33,
                      dtype="float32")
    cfgp = cfg.replace(pad_heads=8, pad_vocab=48)
    params = T.init_lm(cfg, rng)
    pp = T.init_lm(cfgp, rng)
    hd = cfg.hd
    for nm in ("wq", "wk", "wv"):
        pp["blocks"]["s0_mix"][nm] = pp["blocks"]["s0_mix"][nm] \
            .at[:, :, :5 * hd].set(params["blocks"]["s0_mix"][nm])
    pp["blocks"]["s0_mix"]["wo"] = pp["blocks"]["s0_mix"]["wo"] \
        .at[:, :5 * hd, :].set(params["blocks"]["s0_mix"]["wo"]) \
        .at[:, 5 * hd:, :].set(999.0)
    for k in ("s0_n1", "s0_n2", "s0_ffn"):
        pp["blocks"][k] = params["blocks"][k]
    pp["final_norm"] = params["final_norm"]
    pp["embed"]["tok"] = pp["embed"]["tok"].at[:33].set(
        params["embed"]["tok"]).at[33:].set(777.0)
    toks = jax.random.randint(rng, (2, 12), 0, 33)
    l1, _ = T.lm_logits(cfg, params, toks, remat=False)
    l2, _ = T.lm_logits(cfgp, pp, toks, remat=False)
    np.testing.assert_allclose(l1, l2[..., :33], atol=1e-5)
    assert float(l2[..., 33:].max()) < -1e29
