"""The unified FedAlgorithm/FedEngine API: golden parity against the seed
DSFLEngine, all three algorithms through one engine, typed-state
checkpointing, and the chunked open-batch inference path."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import (BatchCtx, DSFLAlgorithm, FDAlgorithm,
                                   FDConfig, FedAvgAlgorithm, FedAvgConfig,
                                   RoundState)
from repro.core.client import predict_probs
from repro.core.engine import FedEngine, make_eval_fn
from repro.core.protocol import DSFLConfig, DSFLEngine
from repro.core.protocol import make_eval_fn as seed_make_eval_fn
from repro.data.pipeline import build_image_task
from repro.models.smallnets import apply_mnist_cnn, init_mnist_cnn

K = 4


def _init(k):
    return init_mnist_cnn(k, image_hw=16, widths=(8, 16), fc=32)


@pytest.fixture(scope="module")
def task():
    return build_image_task(seed=0, K=K, n_private=320, n_open=160,
                            n_test=160, distribution="non_iid")


@pytest.fixture(scope="module")
def client_params(rng):
    wg, sg = _init(rng)
    wk = jax.vmap(lambda k: _init(k)[0])(jax.random.split(rng, K))
    sk = jax.vmap(lambda k: _init(k)[1])(jax.random.split(rng, K))
    return wk, sk, wg, sg


HP = DSFLConfig(rounds=2, local_epochs=1, distill_epochs=1, batch_size=40,
                open_batch=80, aggregation="era")


# ------------------------------------------------------------ golden parity --
def test_fedengine_dsfl_matches_seed_engine_bitwise(task, client_params):
    """The redesigned engine must reproduce the reference DSFLEngine metrics
    bit-for-bit on a fixed seed (same ops, same RNG splits, same jit)."""
    wk, sk, wg, sg = client_params
    seed_eng = DSFLEngine(apply_mnist_cnn, HP,
                          seed_make_eval_fn(apply_mnist_cnn, task.x_test,
                                            task.y_test))
    seed_eng.run(wk, sk, wg, sg, task.x_clients, task.y_clients, task.open_x)

    algo = DSFLAlgorithm(apply_mnist_cnn, HP)
    eng = FedEngine(algo, make_eval_fn(apply_mnist_cnn, task.x_test,
                                       task.y_test))
    state = algo.init_from(wk, sk, wg, sg)
    eng.run(state, task)

    assert len(seed_eng.history) == len(eng.history) == HP.rounds
    for a, b in zip(seed_eng.history, eng.history):
        assert set(a) == set(b)
        for key in a:
            assert a[key] == b[key], f"{key}: {a[key]} != {b[key]}"


# ------------------------------------------- all three algorithms, one loop --
def test_fd_through_fedengine_improves(task, client_params):
    wk, sk, _, _ = client_params
    algo = FDAlgorithm(apply_mnist_cnn,
                       FDConfig(rounds=3, local_epochs=1, batch_size=40,
                                gamma=0.1, n_classes=task.n_classes))
    eng = FedEngine(algo, make_eval_fn(apply_mnist_cnn, task.x_test,
                                       task.y_test))
    eng.run(algo.init_from(wk, sk), task)
    accs = [h["test_acc"] for h in eng.history]
    # FD under strong non-IID is a weak learner (paper Fig. 2/5): just above
    # the 10% chance level at this micro scale, and improving
    assert accs[-1] > 0.12, accs
    assert accs[-1] > accs[0]
    # the non-scalar per-class logit table is exposed on last_metrics
    tg = eng.last_metrics["global_logit"]
    assert tg.shape == (task.n_classes, task.n_classes)
    np.testing.assert_allclose(np.sum(np.asarray(tg), -1), 1.0, atol=1e-4)


def test_fedavg_through_fedengine_improves(task, rng):
    w0, s0 = _init(rng)
    algo = FedAvgAlgorithm(apply_mnist_cnn,
                           FedAvgConfig(rounds=5, local_epochs=2,
                                        batch_size=40))
    eng = FedEngine(algo, make_eval_fn(apply_mnist_cnn, task.x_test,
                                       task.y_test))
    eng.run(algo.init_from(w0, s0), task, weights=jnp.ones((K,)))
    accs = [h["test_acc"] for h in eng.history]
    assert accs[-1] > 0.3, accs
    assert accs[-1] >= accs[0]


def test_on_round_hook_can_rewrite_state(task, rng):
    """The un-jitted between-round hook (attack injection etc.)."""
    import dataclasses
    w0, s0 = _init(rng)
    algo = FedAvgAlgorithm(apply_mnist_cnn,
                           FedAvgConfig(rounds=1, local_epochs=1,
                                        batch_size=40))
    frozen_w, frozen_s = _init(jax.random.fold_in(rng, 7))

    def on_round(r, state):
        return dataclasses.replace(state, server=dataclasses.replace(
            state.server, params=frozen_w, model_state=frozen_s))

    eng = FedEngine(algo, on_round=on_round)
    out = eng.run(algo.init_from(w0, s0), task)
    for a, b in zip(jax.tree.leaves(out.server.params),
                    jax.tree.leaves(frozen_w)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- adaptive weighted-ERA ----
def test_weighted_era_learns_to_downweight_label_flipped_client(task,
                                                                client_params):
    """agg_weights=None + aggregation="weighted_era" re-estimates the
    reliability weights every round from the inverse entropy of each
    client's uploaded soft labels (ROADMAP open item, paper §5 "future
    work"): a label-flipped attacker — whose flipped supervision on non-IID
    shards yields wrong *and* diffuse open-set predictions — must end up
    below every honest client, where the old static vector stayed
    uniform."""
    import dataclasses
    wk, sk, wg, sg = client_params
    C = task.n_classes

    def corrupt(probs, xo, rng):
        flipped = jnp.roll(probs[0], 1, axis=-1)     # class-permuted ...
        attacked = 0.5 * flipped + 0.5 / C           # ... and diffuse
        return probs.at[0].set(attacked)

    hp = dataclasses.replace(HP, aggregation="weighted_era")
    algo = DSFLAlgorithm(apply_mnist_cnn, hp, corrupt=corrupt)
    eng = FedEngine(algo)
    eng.run(algo.init_from(wk, sk, wg, sg), task, rounds=2)
    w = np.asarray(eng.last_metrics["agg_weights"])
    np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)
    assert w[0] < w[1:].min(), w

    # a static agg_weights vector still short-circuits the adaptation
    static = DSFLAlgorithm(apply_mnist_cnn, hp, corrupt=corrupt,
                           agg_weights=jnp.ones((K,)))
    eng2 = FedEngine(static)
    eng2.run(static.init_from(wk, sk, wg, sg), task, rounds=1)
    w2 = np.asarray(eng2.last_metrics["agg_weights"])
    np.testing.assert_allclose(w2, np.full(K, 1 / K), atol=1e-6)


# ------------------------------------------------------------ checkpointing --
def test_state_checkpoint_roundtrip(task, client_params, tmp_path):
    wk, sk, wg, sg = client_params
    algo = DSFLAlgorithm(apply_mnist_cnn, HP)
    eng = FedEngine(algo)
    state = eng.run(algo.init_from(wk, sk, wg, sg), task, rounds=1)
    path = os.path.join(tmp_path, "state.msgpack")
    eng.save_state(path, state)
    restored = eng.load_state(path, algo.init_from(wk, sk, wg, sg))
    assert isinstance(restored, RoundState)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_resume_continues_rng_stream(task, client_params, tmp_path):
    """save -> load -> run(start_round=n) must reproduce an uninterrupted
    run exactly: same open-batch draws, same round keys, same metrics."""
    wk, sk, wg, sg = client_params
    algo = DSFLAlgorithm(apply_mnist_cnn, HP)
    full = FedEngine(algo)
    full.run(algo.init_from(wk, sk, wg, sg), task, rounds=2)

    first = FedEngine(algo)
    mid = first.run(algo.init_from(wk, sk, wg, sg), task, rounds=1)
    path = os.path.join(tmp_path, "mid.msgpack")
    first.save_state(path, mid)
    second = FedEngine(algo)
    restored = second.load_state(path, algo.init_from(wk, sk, wg, sg))
    second.run(restored, task, rounds=1, start_round=1)

    assert [h["round"] for h in full.history] == [1, 2]
    # load_state restored round 1's history record; the resumed round
    # appended round 2's — identical to the uninterrupted run's
    assert [h["round"] for h in second.history] == [1, 2]
    for key in full.history[1]:
        assert full.history[1][key] == second.history[1][key], key


def test_resume_without_hand_tracked_start_round(task, client_params,
                                                 tmp_path):
    """load_state restores rounds_done + history, so a plain run() resumes
    the RNG stream — no caller-side start_round bookkeeping."""
    wk, sk, wg, sg = client_params
    algo = DSFLAlgorithm(apply_mnist_cnn, HP)
    full = FedEngine(algo)
    full.run(algo.init_from(wk, sk, wg, sg), task, rounds=2)

    first = FedEngine(algo)
    mid = first.run(algo.init_from(wk, sk, wg, sg), task, rounds=1)
    path = os.path.join(tmp_path, "mid.msgpack")
    first.save_state(path, mid)
    second = FedEngine(algo)
    restored = second.load_state(path, algo.init_from(wk, sk, wg, sg))
    assert second.rounds_done == 1
    assert second.history == first.history
    second.run(restored, task, rounds=1)
    assert second.history == full.history


def test_history_accepts_python_scalar_metrics(task, rng):
    """The history writer must not assume metrics are jax arrays: a plain
    Python float (e.g. from an un-jitted round) used to raise
    AttributeError on .ndim."""
    w0, s0 = _init(rng)
    algo = FedAvgAlgorithm(apply_mnist_cnn,
                           FedAvgConfig(rounds=1, local_epochs=1,
                                        batch_size=40))
    eng = FedEngine(algo)
    state = algo.init_from(w0, s0)
    eng._round = lambda s, c, k: (s, {"py_metric": 0.5,
                                      "vec": jnp.zeros((3,))})
    eng.run(state, task, rounds=1)
    assert eng.history[0]["py_metric"] == 0.5
    assert "vec" not in eng.history[0]


def test_checkpoint_rejects_wrong_algorithm(task, client_params, tmp_path):
    wk, sk, wg, sg = client_params
    dsfl = FedEngine(DSFLAlgorithm(apply_mnist_cnn, HP))
    state = dsfl.algo.init_from(wk, sk, wg, sg)
    path = os.path.join(tmp_path, "state.msgpack")
    dsfl.save_state(path, state)
    fd = FedEngine(FDAlgorithm(apply_mnist_cnn, FDConfig(rounds=1)))
    with pytest.raises(ValueError, match="dsfl"):
        fd.load_state(path, state)


# ----------------------------------------------------- states are pytrees ----
def test_round_state_is_a_pytree(client_params):
    wk, sk, wg, sg = client_params
    algo = DSFLAlgorithm(apply_mnist_cnn, HP)
    state = algo.init_from(wk, sk, wg, sg)
    doubled = jax.tree.map(lambda a: a * 2, state)
    assert isinstance(doubled, RoundState)
    np.testing.assert_allclose(np.asarray(jax.tree.leaves(doubled)[0]),
                               2 * np.asarray(jax.tree.leaves(state)[0]))
    # BatchCtx with absent slots contributes only the present leaves
    ctx = BatchCtx(x=jnp.zeros((2, 3)))
    assert len(jax.tree.leaves(ctx)) == 1


# ------------------------------------------------- chunked open inference ----
def test_predict_probs_chunked_matches_full(task, client_params):
    wk, sk, _, _ = client_params
    w = jax.tree.map(lambda a: a[0], wk)
    s = jax.tree.map(lambda a: a[0], sk)
    full = predict_probs(apply_mnist_cnn, w, s, task.open_x)
    for bs in (32, 50, 160, 1000):   # divides n, ragged tail, ==n, >n
        chunked = predict_probs(apply_mnist_cnn, w, s, task.open_x,
                                batch_size=bs)
        assert chunked.shape == full.shape
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                                   atol=1e-6)
