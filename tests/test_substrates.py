"""Optimizers, losses, checkpointing, data generators, smallnets, attacks."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.checkpoint import load_pytree, save_pytree
from repro.core import attacks
from repro.core.losses import (distill_xent, entropy, log_softmax,
                               softmax_xent, topk_distill_xent,
                               xent_int_labels)
from repro.data import synthetic
from repro.models.base import param_count
from repro.models.smallnets import (apply_imdb_lstm, apply_reuters_dnn,
                                    init_imdb_lstm, init_mnist_cnn,
                                    init_fmnist_cnn, init_reuters_dnn)
from repro.optim import adam, momentum, sgd


# ---------------------------------------------------------------- losses -----
def test_xent_int_equals_onehot(rng):
    logits = jax.random.normal(rng, (8, 5))
    labels = jax.random.randint(rng, (8,), 0, 5)
    a = xent_int_labels(logits, labels)
    b = softmax_xent(logits, jax.nn.one_hot(labels, 5))
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_distill_xent_self_is_entropy(rng):
    logits = jax.random.normal(rng, (6, 7))
    p = jax.nn.softmax(logits, -1)
    # CE(p || p) = H(p)
    np.testing.assert_allclose(distill_xent(logits, p),
                               jnp.mean(entropy(p)), atol=1e-5)


def test_topk_distill_full_k_equals_dense(rng):
    logits = jax.random.normal(rng, (4, 6))
    t = jax.nn.softmax(jax.random.normal(jax.random.fold_in(rng, 1), (4, 6)), -1)
    v, i = jax.lax.top_k(t, 6)
    dense = distill_xent(logits, t)
    sparse = topk_distill_xent(logits, v, i)
    np.testing.assert_allclose(dense, sparse, atol=1e-5)


@given(st.integers(2, 32), st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=20,
          suppress_health_check=[HealthCheck.too_slow])
def test_log_softmax_normalized(C, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, C)) * 5
    ls = log_softmax(x)
    np.testing.assert_allclose(jnp.sum(jnp.exp(ls), -1), 1.0, atol=1e-5)


# ------------------------------------------------------------- optimizers ----
@pytest.mark.parametrize("make_opt", [lambda: sgd(0.1), lambda: momentum(0.05),
                                      lambda: adam(0.1)],
                         ids=["sgd", "momentum", "adam"])
def test_optimizers_converge_on_quadratic(make_opt):
    opt = make_opt()
    params = {"x": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for step in range(200):
        g = jax.tree.map(lambda p: 2 * p, params)   # d/dx |x|^2
        params, state = opt.update(g, params, state, step)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


# ------------------------------------------------------------- checkpoint ----
def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {"a": jax.random.normal(rng, (3, 4)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": [jnp.ones(2), jnp.zeros(3)]},
            "e": jnp.bfloat16(1.5) * jnp.ones((2, 2), jnp.bfloat16)}
    path = os.path.join(tmp_path, "ckpt.msgpack")
    save_pytree(path, tree)
    back = load_pytree(path)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


# ------------------------------------------------------------------- data ----
def test_digits_learnable_structure(rng):
    x, y = synthetic.make_digits(rng, 256)
    assert x.shape == (256, 16, 16, 1) and y.shape == (256,)
    # same-class pairs are closer than cross-class pairs on average
    x0 = x[y == int(y[0])][:10].reshape(-1, 256)
    x1 = x[y != int(y[0])][:10].reshape(-1, 256)
    d_same = np.mean([np.linalg.norm(a - b) for a in x0[:5] for b in x0[5:]])
    d_diff = np.mean([np.linalg.norm(a - b) for a in x0[:5] for b in x1[:5]])
    assert d_same < d_diff


def test_token_lm_domain_structure(rng):
    toks, dom = synthetic.make_token_lm(rng, 32, 64, 128, n_domains=4)
    assert toks.shape == (32, 64) and toks.max() < 128
    # domain-specific vocabulary bias exists
    t0 = np.asarray(toks[dom == 0]).ravel()
    t3 = np.asarray(toks[dom == 3]).ravel()
    if len(t0) and len(t3):
        assert abs(t0.mean() - t3.mean()) > 1.0


# ------------------------------------------------------------- smallnets -----
def test_paper_param_counts(rng):
    for init, paper, tol in [
        (init_mnist_cnn, 583_242, 0.002),
        (init_fmnist_cnn, 2_760_228, 0.001),
        (init_imdb_lstm, 646_338, 0.004),
        (init_reuters_dnn, 5_194_670, 0.0),
    ]:
        p, s = init(rng)
        n = param_count(p) + param_count(s)
        assert abs(n - paper) <= paper * tol + 1, (init.__name__, n, paper)


def test_lstm_and_dnn_forward(rng):
    p, s = init_imdb_lstm(rng, vocab=100, emb=8, hidden=8)
    toks = jax.random.randint(rng, (3, 12), 0, 100)
    logits, _ = apply_imdb_lstm(p, s, toks, True)
    assert logits.shape == (3, 2)
    p, s = init_reuters_dnn(rng, vocab=50, widths=(16, 8))
    x = jax.random.normal(rng, (3, 50))
    logits, ns = apply_reuters_dnn(p, s, x, True)
    assert logits.shape == (3, 46)
    assert not np.allclose(ns["bn1"]["mean"], s["bn1"]["mean"])


# ---------------------------------------------------------------- attacks ----
def test_noisy_labels_rate(rng):
    labels = jax.random.randint(rng, (4, 200), 0, 10)
    noised = attacks.apply_noisy_labels(rng, labels, 10, C=3)
    frac = float(jnp.mean((noised != labels).astype(jnp.float32)))
    assert 0.1 < frac < 0.45        # ~3/10 of classes remapped (self-map possible)


def test_poison_fl_upload_replaces_average(rng):
    K = 5
    wg = {"w": jnp.ones((3,))}
    wx = {"w": jnp.full((3,), 7.0)}
    wm = attacks.poison_fl_upload(wx, wg, K)
    # average of (K-1) copies of wg and the malicious upload == wx
    avg = ((K - 1) * wg["w"] + wm["w"]) / K
    np.testing.assert_allclose(avg, wx["w"], atol=1e-5)
