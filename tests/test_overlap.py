"""The platform-tuning plane and the pipelined LLM exchange.

Covers `launch.platform` (preset registry, XLA_FLAGS merge semantics,
argparse wiring, provenance stamping, async-collective HLO detection) and
pins the LLM-scale pipelined round — `LLMDSFLAlgorithm.round_start` /
``round_finish`` through ``FedEngine.run(overlap=True)`` — bitwise against
the sequential schedule, plain and mesh-sharded.  The CI tier-1 job runs
this on 8 fake CPU devices (the ``cpu8`` tier), so the all-gather in the
exchange is a real multi-device collective there.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import FedEngine
from repro.core.llm_algorithms import LLMDSFLAlgorithm
from repro.core.llm_dsfl import LLMDsflHP
from repro.data.pipeline import build_lm_task
from repro.launch import platform as pf
from repro.models.api import model_init
from repro.models.shardctx import axis_ctx

CFG = get_config("qwen1.5-4b").smoke()
K, B, S = 2, 4, 32


# ------------------------------------------------------------ presets -------
def test_preset_registry_names():
    assert {"default", "cpu8", "overlap", "overlap-cpu8", "x64"} <= set(
        pf.names())
    for name in pf.names():
        p = pf.PRESETS[name]
        assert p.name == name and p.description
        assert all(f.startswith("--xla_") and "=" in f for f in p.xla_flags)


def test_apply_unknown_preset_raises():
    with pytest.raises(ValueError, match="unknown platform preset"):
        pf.apply("definitely-not-a-preset")


@pytest.fixture
def clean_platform(monkeypatch):
    """Isolate preset application: scratch env, no backend-init warning,
    active-preset slot restored afterwards."""
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    monkeypatch.setattr(pf, "backend_initialized", lambda: False)
    monkeypatch.setattr(pf, "_active", pf._active)
    yield


def test_apply_merges_with_ambient_flags(clean_platform, monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "--xla_dump_to=/tmp/d")
    pf.apply("overlap")
    flags = os.environ["XLA_FLAGS"].split()
    assert "--xla_dump_to=/tmp/d" in flags            # ambient survives
    for f in pf.PRESETS["overlap"].xla_flags:
        assert f in flags
    assert pf.active().name == "overlap"


def test_ambient_forced_device_count_wins(clean_platform, monkeypatch):
    monkeypatch.setenv("XLA_FLAGS",
                       "--xla_force_host_platform_device_count=2")
    pf.apply("overlap-cpu8")
    flags = os.environ["XLA_FLAGS"].split()
    # the preset must NOT add a second (conflicting) forced count
    forced = [f for f in flags
              if f.startswith("--xla_force_host_platform_device_count")]
    assert forced == ["--xla_force_host_platform_device_count=2"]
    for f in pf.PRESETS["overlap-cpu8"].xla_flags:
        assert f in flags


def test_apply_without_ambient_sets_device_count(clean_platform):
    pf.apply("cpu8")
    assert ("--xla_force_host_platform_device_count=8"
            in os.environ["XLA_FLAGS"].split())


def test_apply_is_idempotent(clean_platform):
    pf.apply("overlap")
    once = os.environ["XLA_FLAGS"]
    pf.apply("overlap")
    assert os.environ["XLA_FLAGS"] == once


def test_apply_after_backend_init_warns(clean_platform, monkeypatch):
    monkeypatch.setattr(pf, "backend_initialized", lambda: True)
    with pytest.warns(UserWarning, match="after jax backend init"):
        pf.apply("overlap")


def test_from_args_roundtrip(clean_platform):
    import argparse
    ap = argparse.ArgumentParser()
    pf.add_args(ap)
    assert pf.from_args(ap.parse_args([])) is None
    got = pf.from_args(ap.parse_args(["--platform-preset", "cpu8"]))
    assert got is pf.PRESETS["cpu8"]


def test_provenance_stamps_preset(clean_platform):
    from repro.obs.provenance import RunProvenance
    pf.apply("overlap")
    prov = RunProvenance.collect()
    assert prov.platform_preset == "overlap"
    assert "--xla_gpu_enable_latency_hiding_scheduler=true" in prov.xla_flags


def test_async_collectives_in_markers():
    assert pf.async_collectives_in(
        "%ag-start = all-gather-start(f32[8] %x), replica_groups={}")
    assert pf.async_collectives_in("... all-reduce-start ...")
    assert not pf.async_collectives_in(
        "%ag = all-gather(f32[8] %x)")       # sync lowering: no overlap
    assert not pf.async_collectives_in("")


# ------------------------------------------------ LLM pipelined parity ------
@pytest.fixture(scope="module")
def task():
    return build_lm_task(seed=0, K=K, batch=B, seq=S, vocab=CFG.vocab)


@pytest.fixture(scope="module")
def stacked(rng):
    return jax.vmap(lambda k: model_init(CFG, k))(jax.random.split(rng, K))


def _states_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _llm_run(task, stacked, topk, overlap, mesh=None, rounds=4, chunk=2):
    hp = LLMDsflHP(lr=5e-3, rounds=rounds, seed=0, open_batch=B, topk=topk)
    algo = LLMDSFLAlgorithm(CFG, hp)
    eng = FedEngine(algo, mesh=mesh)
    state = eng.run(algo.init_from(stacked), task, rounds=rounds,
                    chunk_rounds=chunk, overlap=overlap)
    return eng, state


@pytest.mark.parametrize("topk", [None, 8])
def test_llm_overlap_bitwise_identical_to_sequential(task, stacked, topk):
    """The LLM tentpole pin, dense and through the top-k wire codec: the
    pipelined exchange (the round's only cross-pod collective issued a
    round early) changes no bits."""
    e1, s1 = _llm_run(task, stacked, topk, overlap=False)
    e2, s2 = _llm_run(task, stacked, topk, overlap=True)
    _states_equal(s1, s2)
    assert e1.history == e2.history


def test_llm_overlap_parity_under_mesh(task, stacked):
    """Same pin on the mesh-sharded engine path (in_shardings jit): on the
    8-fake-device CI tier the exchange all-gather is a real collective."""
    from repro.launch.mesh import make_client_mesh
    mesh = make_client_mesh(K)
    with axis_ctx(mesh, batch_axes=("data",)):
        e1, s1 = _llm_run(task, stacked, 8, overlap=False, mesh=mesh)
        e2, s2 = _llm_run(task, stacked, 8, overlap=True, mesh=mesh)
    _states_equal(s1, s2)
    assert e1.history == e2.history


def test_overlap_telemetry_is_host_side_and_published(tmp_path):
    """Satellite pin: the pipelined path emits `wire.exchange`/`overlap`
    instants (at chunk boundaries — never inside the compiled chunk) and,
    once both schedules have been timed, the `engine.comm_hidden_us`
    gauge; instrumentation must not change a bit of the history."""
    import json

    from repro import obs
    from repro.core.algorithms import DSFLAlgorithm
    from repro.core.protocol import DSFLConfig
    from repro.data.pipeline import build_image_task
    from repro.models.smallnets import apply_tiny_mlp, init_tiny_mlp

    hp = DSFLConfig(rounds=4, local_epochs=1, distill_epochs=1,
                    batch_size=20, open_batch=40, aggregation="era")
    itask = build_image_task(seed=0, K=4, n_private=160, n_open=80,
                             n_test=40, distribution="non_iid")

    def go(traced):
        eng = FedEngine(DSFLAlgorithm(apply_tiny_mlp, hp))
        for overlap in (False, True):
            state = eng.init(init_tiny_mlp, itask)
            eng.run(state, itask, rounds=4, chunk_rounds=2, overlap=overlap)
        if traced:
            return eng.history, obs.current_registry()
        return eng.history, None

    plain, _ = go(traced=False)
    path = os.path.join(tmp_path, "overlap.jsonl")
    with obs.trace_to(str(path)):
        prev = obs.install_registry(obs.MetricsRegistry())
        try:
            traced, reg = go(traced=True)
            hidden = reg.gauge("engine.comm_hidden_us").value
        finally:
            obs.install_registry(prev)
    assert traced == plain                     # host-side only: same bits
    assert hidden is not None                  # both schedules timed
    names = [json.loads(l).get("name") for l in open(path) if l.strip()]
    assert "wire.exchange" in names and "overlap" in names


def test_llm_round_equals_finish_of_start(task, stacked):
    """The split identity the pipeline is built on:
    round == round_finish(state, ctx, round_start(state, ctx, rng), rng)."""
    from repro.core.algorithms import BatchCtx, EMPTY
    hp = LLMDsflHP(lr=5e-3, rounds=1, seed=0, open_batch=B, topk=8)
    algo = LLMDSFLAlgorithm(CFG, hp)
    state = algo.init_from(stacked)
    o_idx = jnp.arange(B)
    ctx = BatchCtx(x=task.x_clients, open_x=task.open_x, o_idx=o_idx,
                   mask=EMPTY, stale=EMPTY, active_budget=None)
    rng = jax.random.PRNGKey(0)
    s_ref, m_ref = jax.jit(algo.round)(state, ctx, rng)
    split = jax.jit(lambda s, c, r: algo.round_finish(
        s, c, algo.round_start(s, c, r), r))
    s_got, m_got = split(state, ctx, rng)
    _states_equal(s_ref, s_got)
    _states_equal(m_ref, m_got)
