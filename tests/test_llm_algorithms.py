"""Pod-scale LLM algorithms on the unified FedAlgorithm/FedEngine API:
golden parity against the raw `llm_dsfl` round steps (bit-for-bit — the CI
tier-1 job runs this on 8 fake CPU devices), mesh-aware engine jit with
`launch.sharding` placements, wire/comm parity of the top-k LLM payload, and
checkpoint resume without hand-tracked round counters."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import wire
from repro.core.comm import CommModel
from repro.core.engine import FedEngine
from repro.core.llm_algorithms import (LLMDSFLAlgorithm, LLMFedAvgAlgorithm,
                                       LLMFedAvgHP)
from repro.core.llm_dsfl import (LLMDsflHP, dsfl_round_step,
                                 fedavg_round_step)
from repro.data.pipeline import build_lm_task
from repro.models.api import model_init
from repro.models.shardctx import axis_ctx

CFG = get_config("qwen1.5-4b").smoke()
K, B, S = 2, 4, 32


@pytest.fixture(scope="module")
def task():
    return build_lm_task(seed=0, K=K, batch=B, seq=S, vocab=CFG.vocab)


@pytest.fixture(scope="module")
def stacked(rng):
    return jax.vmap(lambda k: model_init(CFG, k))(jax.random.split(rng, K))


def _engine_open_batch(hp, task):
    """Replicate FedEngine's round-0 RNG stream: the o_r draw."""
    rng = jax.random.PRNGKey(hp.seed)
    _, _, ri = jax.random.split(rng, 3)
    n_open = jax.tree.leaves(task.open_x)[0].shape[0]
    n_r = min(hp.open_batch, n_open)
    return jax.random.choice(ri, n_open, (n_r,), replace=False)


# ------------------------------------------------------------ golden parity --
def test_llm_dsfl_engine_matches_round_step_bitwise(task, stacked):
    """One engine round must equal the raw dsfl_round_step exactly (same
    gather, same ops, same jit) — the LLM analogue of the DSFLEngine
    golden-parity pin."""
    hp = LLMDsflHP(lr=5e-3, rounds=1, seed=0, open_batch=B)
    algo = LLMDSFLAlgorithm(CFG, hp)
    eng = FedEngine(algo)
    out = eng.run(algo.init_from(stacked), task, rounds=1)

    o_idx = _engine_open_batch(hp, task)
    ref, ref_loss = jax.jit(
        lambda p, pb, ox, oi: dsfl_round_step(
            CFG, p, pb, jax.tree.map(lambda a: jnp.take(a, oi, axis=0), ox),
            hp))(stacked, task.x_clients, task.open_x, o_idx)
    for a, b in zip(jax.tree.leaves(out.clients.params), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert eng.history[0]["loss"] == float(ref_loss)


def test_llm_fedavg_engine_matches_round_step_bitwise(task, stacked):
    algo = LLMFedAvgAlgorithm(CFG, LLMFedAvgHP(lr=1e-3, rounds=1))
    eng = FedEngine(algo)
    out = eng.run(algo.init_from(stacked), task, rounds=1)
    ref, _ = jax.jit(
        lambda p, pb: fedavg_round_step(CFG, p, pb, 1e-3))(
        stacked, task.x_clients)
    for a, b in zip(jax.tree.leaves(out.clients.params), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the round's broadcast synced the clients
    for leaf in jax.tree.leaves(out.clients.params):
        np.testing.assert_allclose(np.asarray(leaf[0], np.float32),
                                   np.asarray(leaf[1], np.float32), atol=1e-6)


# --------------------------------------------------- mesh-aware engine jit ---
def _pod_mesh():
    from repro.launch.mesh import make_client_mesh
    return make_client_mesh(K)


def test_llm_dsfl_sharded_engine_round_runs(task, stacked, tmp_path):
    """End-to-end through FedEngine(mesh=...): in_shardings from
    algo.shardings (client axis on "pod"), donated state.  On the CI job this
    exercises 8 fake CPU devices; on one device the same code path runs on a
    (1, 1, 1) mesh."""
    hp = LLMDsflHP(lr=5e-3, rounds=1, seed=0, open_batch=B)
    algo = LLMDSFLAlgorithm(CFG, hp)
    mesh = _pod_mesh()
    eng = FedEngine(algo, mesh=mesh, donate_state=True)
    state = algo.init_from(jax.tree.map(jnp.copy, stacked))
    with axis_ctx(mesh, batch_axes=("data",)):
        out = eng.run(state, task, rounds=1)
    assert np.isfinite(eng.history[0]["loss"])
    # msgpack checkpoint of the sharded state: restore straight onto shards
    path = os.path.join(tmp_path, "sharded.msgpack")
    eng.save_state(path, out)
    ctx = eng.make_ctx(task, o_idx=jnp.zeros((B,), jnp.int32))
    st_sh, _ = algo.shardings(mesh, out, ctx)
    restored = eng.load_state(path, algo.init_from(stacked), shardings=st_sh)
    for a, b in zip(jax.tree.leaves(out.clients.params),
                    jax.tree.leaves(restored.clients.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    pod_size = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]
    if pod_size > 1:
        # the client-stacked params actually live on the pod axis
        sh = jax.tree.leaves(out.clients.params)[0].sharding
        assert "pod" in sh.spec
    # sharded result must agree with the unsharded reference
    o_idx = _engine_open_batch(hp, task)
    ref, _ = jax.jit(
        lambda p, pb, ox, oi: dsfl_round_step(
            CFG, p, pb, jax.tree.map(lambda a: jnp.take(a, oi, axis=0), ox),
            hp))(stacked, task.x_clients, task.open_x, o_idx)
    for a, b in zip(jax.tree.leaves(out.clients.params), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-2, rtol=1e-2)


def test_llm_dsfl_sharded_engine_chunked_scan_parity(task, stacked):
    """chunk_rounds composes with mesh= in_shardings + donate_state: two
    scanned rounds equal two per-round loop rounds bitwise (also pins the
    out_shardings fix — round 2 consumes round 1's output placement)."""
    hp = LLMDsflHP(lr=5e-3, rounds=2, seed=0, open_batch=B)
    algo = LLMDSFLAlgorithm(CFG, hp)
    mesh = _pod_mesh()

    def go(chunk):
        eng = FedEngine(algo, mesh=mesh, donate_state=True)
        state = algo.init_from(jax.tree.map(jnp.copy, stacked))
        with axis_ctx(mesh, batch_axes=("data",)):
            out = eng.run(state, task, rounds=2, chunk_rounds=chunk)
        return eng, out

    e1, o1 = go(1)
    e2, o2 = go(2)
    assert e1.history == e2.history
    for a, b in zip(jax.tree.leaves(o1.clients.params),
                    jax.tree.leaves(o2.clients.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    pod_size = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]
    if pod_size > 1:
        sh = jax.tree.leaves(o2.clients.params)[0].sharding
        assert "pod" in sh.spec


# ------------------------------------------------------- wire/comm parity ----
def test_llm_topk_measured_bytes_match_comm_model(task, stacked):
    """The LLM exchange's measured top-k bytes == CommModel.dsfl_topk_round
    with per-token payloads (|o_r| * S distribution uploads of k pairs)."""
    k = 8
    hp = LLMDsflHP(topk=k, rounds=1, open_batch=B)
    algo = LLMDSFLAlgorithm(CFG, hp)
    eng = FedEngine(algo, codec=wire.TopKCodec(k=k, n_classes=CFG.vocab))
    state = algo.init_from(stacked)
    cm = CommModel(K, CFG.vocab, 0, open_batch=B * S)
    assert eng.measured_round_bytes(state, task) == cm.dsfl_topk_round(k)


def test_llm_fp16_measured_bytes_match_comm_model(task, stacked):
    hp = LLMDsflHP(rounds=1, open_batch=B)
    algo = LLMDSFLAlgorithm(CFG, hp)
    eng = FedEngine(algo, codec=wire.FP16Codec())
    state = algo.init_from(stacked)
    cm = CommModel(K, CFG.vocab, 0, open_batch=B * S)
    assert eng.measured_round_bytes(state, task) == cm.dsfl_fp16_round()


# ------------------------------------------------------------ checkpointing --
def test_llm_engine_resume_without_start_round(task, stacked, tmp_path):
    """save -> load -> run continues the RNG stream automatically: the
    engine checkpoints rounds_done + history alongside the sharded state."""
    hp = LLMDsflHP(lr=5e-3, rounds=2, seed=0, open_batch=B)
    algo = LLMDSFLAlgorithm(CFG, hp)
    full = FedEngine(algo)
    out_full = full.run(algo.init_from(stacked), task)

    first = FedEngine(algo)
    mid = first.run(algo.init_from(stacked), task, rounds=1)
    path = os.path.join(tmp_path, "llm.msgpack")
    first.save_state(path, mid)

    second = FedEngine(algo)
    restored = second.load_state(path, algo.init_from(stacked))
    assert second.rounds_done == 1
    assert second.history == first.history
    out_resumed = second.run(restored, task, rounds=1)   # no start_round
    assert [h["round"] for h in second.history] == [1, 2]
    assert second.history == full.history
    for a, b in zip(jax.tree.leaves(out_full.clients.params),
                    jax.tree.leaves(out_resumed.clients.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
