"""Per-assigned-architecture smoke tests: a REDUCED variant of the same
family (2 pattern-repeats, d_model<=512, <=4 experts) runs one forward and
one train step on CPU with correct output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.core.llm_dsfl import sgd_train_step
from repro.models.api import model_init, model_logits

ARCHS = list_archs()


def smoke_batch(cfg, key, B=2, S=16):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)}
    if cfg.arch_type == "vlm":
        b["patches"] = jax.random.normal(key, (B, cfg.n_patches, cfg.d_model),
                                         jnp.float32)
    if cfg.arch_type == "audio":
        b["frames"] = jax.random.normal(key, (B, cfg.n_audio_frames,
                                              cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward(rng, arch):
    cfg = get_config(arch).smoke()
    params = model_init(cfg, rng)
    batch = smoke_batch(cfg, rng)
    logits, aux = model_logits(cfg, params, batch, remat=False)
    assert logits.shape == (2, 16, cfg.vocab), (arch, logits.shape)
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(aux)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(rng, arch):
    cfg = get_config(arch).smoke()
    params = model_init(cfg, rng)
    batch = smoke_batch(cfg, rng)
    new, loss = jax.jit(lambda p, b: sgd_train_step(cfg, p, b, 1e-2))(params,
                                                                      batch)
    assert bool(jnp.isfinite(loss)), arch
    # parameters actually moved
    moved = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new)
    assert any(jax.tree.leaves(moved)), arch


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "mamba2-2.7b",
                                  "jamba-1.5-large-398b", "whisper-small",
                                  "phi-3-vision-4.2b"])
def test_arch_smoke_decode(rng, arch):
    from repro.models.api import model_decode_step, model_init_cache
    cfg = get_config(arch).smoke()
    params = model_init(cfg, rng)
    batch = smoke_batch(cfg, rng)
    cache = model_init_cache(cfg, params, 2, 32, batch)
    tok = batch["tokens"][:, 0]
    logits, cache2 = model_decode_step(cfg, params, cache, tok, jnp.int32(0))
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch
