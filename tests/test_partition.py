"""Property tests for the federated partitioners."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.data import partition

SETTINGS = dict(deadline=None, max_examples=20,
                suppress_health_check=[HealthCheck.too_slow])


@given(st.integers(40, 200), st.integers(2, 8), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_iid_partition_disjoint_and_covers(n, K, seed):
    idx = partition.iid(jax.random.PRNGKey(seed), n, K)
    flat = np.asarray(idx).ravel()
    assert len(set(flat.tolist())) == len(flat)          # disjoint
    assert idx.shape == (K, n // K)
    assert flat.max() < n


@given(st.integers(2, 6), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_shard_non_iid_disjoint(K, seed):
    key = jax.random.PRNGKey(seed)
    n = K * 40
    labels = jax.random.randint(key, (n,), 0, 10)
    idx = partition.shard_non_iid(jax.random.fold_in(key, 1), labels, K, 2)
    flat = np.asarray(idx).ravel()
    assert len(set(flat.tolist())) == len(flat)


def test_shard_non_iid_limits_classes_at_paper_scale(rng):
    """At the paper's scale (shards >> classes) each client sees ~2-4
    classes: 2 contiguous label-sorted shards cross <= 1 boundary each."""
    n, K = 2000, 10
    labels = jax.random.randint(rng, (n,), 0, 10)
    idx = partition.shard_non_iid(jax.random.fold_in(rng, 1), labels, K, 2)
    labels_np = np.asarray(labels)
    counts = [len(set(labels_np[np.asarray(idx[k])].tolist()))
              for k in range(K)]
    assert max(counts) <= 4 and np.mean(counts) <= 3.2, counts


@given(st.integers(2, 5), st.sampled_from([0.1, 1.0, 100.0]),
       st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=10,
          suppress_health_check=[HealthCheck.too_slow])
def test_dirichlet_partition_valid(K, alpha, seed):
    key = jax.random.PRNGKey(seed)
    labels = jax.random.randint(key, (400,), 0, 10)
    idx = partition.dirichlet(jax.random.fold_in(key, 1), labels, K, alpha, 10)
    flat = np.asarray(idx).ravel()
    assert len(set(flat.tolist())) == len(flat)
    assert idx.shape[0] == K and idx.shape[1] > 0


def test_ratio_non_iid_ratios(rng):
    labels = jnp.concatenate([jnp.zeros(500, jnp.int32),
                              jnp.ones(500, jnp.int32)])
    idx = partition.ratio_non_iid(rng, labels, 4, 0.9)
    labels_np = np.asarray(labels)
    for k in range(4):
        frac_pos = labels_np[np.asarray(idx[k])].mean()
        assert frac_pos > 0.85 or frac_pos < 0.15


def test_gather_clients_shapes(rng):
    x = jnp.arange(40.0).reshape(20, 2)
    y = jnp.arange(20)
    idx = partition.iid(rng, 20, 4)
    xc, yc = partition.gather_clients(x, y, idx)
    assert xc.shape == (4, 5, 2) and yc.shape == (4, 5)
