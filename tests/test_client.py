"""Client-local loop regressions: `local_update` must clamp ``batch_size``
to the private-set size the way `local_distill` always has — ``batch_size >
n`` used to give zero batches per epoch, an empty scan, and a mean over
zero losses -> NaN metrics (with the parameters silently never trained)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import LocalSpec, local_distill, local_update
from repro.models.smallnets import apply_tiny_mlp, init_tiny_mlp
from repro.optim import optimizers as opt_lib


def _setup(rng, n):
    params, state = init_tiny_mlp(rng)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (n, 16, 16, 1))
    y = jax.random.randint(jax.random.fold_in(rng, 2), (n,), 0, 10)
    return params, state, x, y


def test_local_update_clamps_batch_size_to_n(rng):
    n = 12
    params, state, x, y = _setup(rng, n)
    spec = LocalSpec(apply_tiny_mlp, opt_lib.make("sgd", 0.1), 2,
                     batch_size=100)          # > n: one clamped batch
    opt0 = spec.opt.init(params)
    new_p, _, _, loss = jax.jit(
        lambda p, s, o, xx, yy, k: local_update(spec, p, s, o, xx, yy, k)
    )(params, state, opt0, x, y, rng)
    assert bool(jnp.isfinite(loss)), "batch_size > n must not NaN the loss"
    # and it actually trains: at least one parameter leaf moved
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_p)))
    assert moved, "clamped batch must still run update steps"


def test_local_update_clamp_matches_explicit_batch_size(rng):
    """Clamping is exactly ``bs = min(batch_size, n)``: an oversized
    batch_size produces bitwise the run an explicit batch_size=n does."""
    n = 12
    params, state, x, y = _setup(rng, n)
    outs = []
    for bs in (n, 10 * n):
        spec = LocalSpec(apply_tiny_mlp, opt_lib.make("sgd", 0.1), 1, bs)
        outs.append(local_update(spec, params, state, spec.opt.init(params),
                                 x, y, rng))
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_local_distill_clamp_still_finite(rng):
    """The pre-existing distill clamp keeps working alongside the new
    update clamp (same spec, both loops)."""
    n = 8
    params, state, x, _ = _setup(rng, n)
    teacher = jax.nn.softmax(jax.random.normal(rng, (n, 10)), -1)
    spec = LocalSpec(apply_tiny_mlp, opt_lib.make("sgd", 0.1), 1, 64)
    _, _, _, loss = local_distill(spec, params, state, spec.opt.init(params),
                                  x, teacher, rng)
    assert bool(jnp.isfinite(loss))
