"""Integration tests of the federated engines (paper-scale substrate)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import CommModel
from repro.core.fd import aggregate_fd, distill_targets, per_label_logits
from repro.core.fedavg import weighted_average
from repro.core.protocol import DSFLConfig, DSFLEngine, make_eval_fn
from repro.data.pipeline import build_image_task
from repro.models.smallnets import apply_mnist_cnn, init_mnist_cnn

K = 4


@pytest.fixture(scope="module")
def task():
    return build_image_task(seed=0, K=K, n_private=640, n_open=320,
                            n_test=320, distribution="non_iid")


@pytest.fixture(scope="module")
def small_init():
    def init(k):
        return init_mnist_cnn(k, image_hw=16, widths=(8, 16), fc=32)
    return init


def test_dsfl_engine_improves_accuracy(task, small_init, rng):
    wg, sg = small_init(rng)
    wk = jax.vmap(lambda k: small_init(k)[0])(jax.random.split(rng, K))
    sk = jax.vmap(lambda k: small_init(k)[1])(jax.random.split(rng, K))
    hp = DSFLConfig(rounds=4, local_epochs=2, distill_epochs=2, batch_size=40,
                    open_batch=160, aggregation="era")
    eng = DSFLEngine(apply_mnist_cnn, hp,
                     make_eval_fn(apply_mnist_cnn, task.x_test, task.y_test))
    eng.run(wk, sk, wg, sg, task.x_clients, task.y_clients, task.open_x)
    accs = [h["test_acc"] for h in eng.history]
    assert accs[-1] > 0.3, accs            # well above 10% chance
    assert accs[-1] > accs[0]


def test_era_entropy_below_sa_entropy(task, small_init, rng):
    wg, sg = small_init(rng)
    wk = jax.vmap(lambda k: small_init(k)[0])(jax.random.split(rng, K))
    sk = jax.vmap(lambda k: small_init(k)[1])(jax.random.split(rng, K))
    hp = DSFLConfig(rounds=2, local_epochs=1, distill_epochs=1, batch_size=40,
                    open_batch=160, aggregation="era")
    eng = DSFLEngine(apply_mnist_cnn, hp,
                     make_eval_fn(apply_mnist_cnn, task.x_test, task.y_test))
    eng.run(wk, sk, wg, sg, task.x_clients, task.y_clients, task.open_x)
    for h in eng.history:
        assert h["global_entropy"] <= h["sa_entropy"] + 1e-5


# --------------------------------------------------------------- FedAvg ------
def test_weighted_average_recovers_mean(rng):
    stacked = {"w": jnp.arange(12.0).reshape(3, 4)}
    avg = weighted_average(stacked, jnp.ones((3,)))
    np.testing.assert_allclose(avg["w"], jnp.mean(stacked["w"], 0), atol=1e-6)
    w = jnp.array([1.0, 0.0, 0.0])
    avg = weighted_average(stacked, w)
    np.testing.assert_allclose(avg["w"], stacked["w"][0], atol=1e-6)


# ------------------------------------------------------------------- FD ------
def test_fd_per_label_logits_shapes(task, small_init, rng):
    w, s = small_init(rng)
    t, present = per_label_logits(apply_mnist_cnn, w, s,
                                  task.x_clients[0], task.y_clients[0], 10)
    assert t.shape == (10, 10) and present.shape == (10,)
    # strong non-IID: each client holds ~2 classes
    assert int(present.sum()) <= 4


def test_fd_aggregate_and_debias(rng):
    K, C = 3, 4
    tk = jax.nn.softmax(jax.random.normal(rng, (K, C, C)), -1)
    present = jnp.ones((K, C), bool)
    tg, n_own = aggregate_fd(tk, present)
    np.testing.assert_allclose(n_own, 3.0)
    tgt = distill_targets(tg, tk[0], n_own, jnp.arange(C))
    # Eq. 6: (K*tg - tk)/(K-1) must average back to tg
    recon = (tgt + tk[0][jnp.arange(C)] / 2) * 2 / 3
    np.testing.assert_allclose(jnp.sum(tgt, -1), 1.0, atol=1e-4)


# ------------------------------------------------------------- comm model ----
def test_comm_model_reproduces_paper_tables():
    # Table 1 (image tasks, K=100) and Table 2 (text tasks, K=10)
    mnist = CommModel(100, 10, 583_242, 1000)
    assert abs(mnist.fl_round() - 236.1e6) / 236.1e6 < 0.01
    assert abs(mnist.fd_round() - 40.4e3) / 40.4e3 < 0.01
    assert abs(mnist.dsfl_round() - 4.0e6) / 4.0e6 < 0.02
    fmnist = CommModel(100, 10, 2_760_228, 1000)
    assert abs(fmnist.fl_round() - 1.1e9) / 1.1e9 < 0.02
    imdb = CommModel(10, 2, 646_338, 1000)
    assert abs(imdb.fl_round() - 28.6e6) / 28.6e6 < 0.01
    assert imdb.fd_round() == 176
    assert imdb.dsfl_round() == 88_000
    reuters = CommModel(10, 46, 5_194_670, 1000)
    assert abs(reuters.fl_round() - 228.8e6) / 228.8e6 < 0.01
    assert abs(reuters.fd_round() - 93e3) / 93e3 < 0.02
    assert abs(reuters.dsfl_round() - 2.0e6) / 2.0e6 < 0.02


def test_topk_exchange_is_cheaper():
    cm = CommModel(10, 202_048, 10**9, 1000)   # LLM-scale vocab
    assert cm.dsfl_topk_round(32) < cm.dsfl_round() / 100
