import jax
import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests and
# benches must see the single real device; only launch/dryrun.py fakes 512.
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
