"""Pod-scale DS-FL round step: convergence, FedAvg equivalence, top-k path,
microbatch-accumulation equivalence, attack surface."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.llm_dsfl import (LLMDsflHP, dsfl_client_step, dsfl_round_step,
                                 fedavg_round_step, predict_open_probs)
from repro.data.pipeline import lm_open_batch, lm_private_batches
from repro.models.api import model_init

CFG = get_config("qwen1.5-4b").smoke()
K = 2


def make_setup(rng, batch=4, seq=32):
    stacked = jax.vmap(lambda k: model_init(CFG, k))(jax.random.split(rng, K))
    private = lm_private_batches(jax.random.fold_in(rng, 1), K, batch, seq,
                                 CFG.vocab)
    open_b = lm_open_batch(jax.random.fold_in(rng, 2), batch, seq, CFG.vocab)
    return stacked, private, open_b


def test_dsfl_round_reduces_loss(rng):
    stacked, private, open_b = make_setup(rng)
    hp = LLMDsflHP(lr=5e-3)
    step = jax.jit(lambda p, pb, ob: dsfl_round_step(CFG, p, pb, ob, hp))
    losses = []
    params = stacked
    for _ in range(8):
        params, loss = step(params, private, open_b)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_dsfl_round_topk_path_runs(rng):
    stacked, private, open_b = make_setup(rng)
    hp = LLMDsflHP(lr=5e-3, topk=8)
    params, loss = jax.jit(
        lambda p, pb, ob: dsfl_round_step(CFG, p, pb, ob, hp))(
        stacked, private, open_b)
    assert bool(jnp.isfinite(loss))


def test_fedavg_round_syncs_clients(rng):
    stacked, private, _ = make_setup(rng)
    new, loss = jax.jit(
        lambda p, pb: fedavg_round_step(CFG, p, pb, 1e-3))(stacked, private)
    for leaf in jax.tree.leaves(new):
        np.testing.assert_allclose(leaf[0], leaf[1], atol=1e-6)


def test_microbatch_accumulation_matches_full_batch(rng):
    params = model_init(CFG, rng)
    private = lm_open_batch(jax.random.fold_in(rng, 1), 4, 32, CFG.vocab)
    open_b = lm_open_batch(jax.random.fold_in(rng, 2), 4, 32, CFG.vocab)
    teacher = jax.nn.softmax(
        jax.random.normal(rng, (4, 32, CFG.vocab)), -1).astype(jnp.bfloat16)
    hp1 = LLMDsflHP(lr=1e-2, microbatches=1)
    hp2 = LLMDsflHP(lr=1e-2, microbatches=2)
    p1, l1 = dsfl_client_step(CFG, params, private, open_b, teacher, hp1)
    p2, l2 = dsfl_client_step(CFG, params, private, open_b, teacher, hp2)
    # CE means over microbatches == mean over full batch (equal sizes)
    assert abs(float(l1) - float(l2)) < 5e-2
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-2, rtol=1e-2)


def test_sparse_round_bitwise_matches_dense_weights_path(rng):
    """Participation-sparse pod rounds: ``active_budget=1`` on a 2-pod
    fleet (one absent client) computes half the client stack and is
    bitwise identical to the dense ``weights=`` round — for DS-FL and for
    the FedAvg benchmark twin."""
    stacked, private, open_b = make_setup(rng)
    hp = LLMDsflHP(lr=5e-3)
    mask = jnp.asarray([1.0, 0.0])
    w = mask * 0.7

    d = jax.jit(lambda p, pb, ob: dsfl_round_step(
        CFG, p, pb, ob, hp, weights=w, mask=mask))(stacked, private, open_b)
    s = jax.jit(lambda p, pb, ob: dsfl_round_step(
        CFG, p, pb, ob, hp, weights=w, mask=mask, active_budget=1))(
        stacked, private, open_b)
    for a, b in zip(jax.tree.leaves(d), jax.tree.leaves(s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    d = jax.jit(lambda p, pb: fedavg_round_step(
        CFG, p, pb, 1e-3, weights=w, mask=mask))(stacked, private)
    s = jax.jit(lambda p, pb: fedavg_round_step(
        CFG, p, pb, 1e-3, weights=w, mask=mask, active_budget=1))(
        stacked, private)
    for a, b in zip(jax.tree.leaves(d), jax.tree.leaves(s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_predict_open_probs_is_distribution(rng):
    params = model_init(CFG, rng)
    open_b = lm_open_batch(rng, 2, 16, CFG.vocab)
    probs = predict_open_probs(CFG, params, open_b)
    np.testing.assert_allclose(np.sum(np.asarray(probs, np.float32), -1),
                               1.0, atol=2e-2)


def test_poisoned_logits_are_diluted_by_era(rng):
    """DS-FL's attack surface: one malicious client's adversarial logits get
    averaged away (Table 4 mechanism) — the aggregated teacher stays closer
    to the benign mean than to the attacker's distribution."""
    from repro.core.aggregation import era
    kb, km = jax.random.split(rng)
    # benign clients share a consensus signal (they model the same task)
    consensus = jax.random.normal(km, (1, 32, 16)) * 2.0
    benign = jax.nn.softmax(consensus
                            + jax.random.normal(kb, (7, 32, 16)), -1)
    target = jax.nn.one_hot(jnp.zeros((32,), jnp.int32), 16)[None]
    probs = jnp.concatenate([benign, target], axis=0)
    g = era(probs, 0.1)
    benign_mean = jnp.mean(benign, 0)
    attacker_mass = float(jnp.mean(g[:, 0]))
    benign_top = float(jnp.mean(jnp.max(benign_mean, -1)))
    agree = np.mean(np.argmax(np.asarray(g), -1)
                    == np.argmax(np.asarray(benign_mean), -1))
    assert agree > 0.8
