"""Property-based tests (hypothesis) for the simulation layer's core
invariants: a participation mask must be *exactly* equivalent to deleting
the masked-out clients' uploads before aggregation, staleness decay must
only ever shrink weights, and the sync scheduler's virtual-time accounting
must close the round at the slowest surviving client (or the deadline)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import aggregation as agg
from repro.core.algorithms import active_indices
from repro.sim import (AsyncBufferScheduler, ClientPopulation, SyncScheduler,
                       cohort_available, floyd_sample)
from repro.sim.clients import weighted_draw_ids

SETTINGS = dict(deadline=None, max_examples=30,
                suppress_health_check=[HealthCheck.too_slow])


@st.composite
def probs_and_mask(draw, max_k=8, max_n=5, max_c=8):
    K = draw(st.integers(2, max_k))
    N = draw(st.integers(1, max_n))
    C = draw(st.integers(2, max_c))
    seed = draw(st.integers(0, 2**31 - 1))
    mask = np.array(draw(st.lists(st.booleans(), min_size=K, max_size=K)))
    if not mask.any():
        mask[draw(st.integers(0, K - 1))] = True
    logits = jax.random.normal(jax.random.PRNGKey(seed), (K, N, C)) * 3
    return jax.nn.softmax(logits, -1), mask


@given(probs_and_mask(), st.sampled_from(["era", "sa"]))
@settings(**SETTINGS)
def test_mask_identical_to_deleting_uploads(pm, method):
    """Zero-weight clients contribute exactly nothing: aggregating the full
    (K, n, C) stack under a participation mask equals aggregating only the
    participants' uploads — bitwise, not approximately."""
    p, mask = pm
    w = agg.participation_weights(jnp.asarray(mask, jnp.float32))
    sub = p[np.flatnonzero(mask)]
    ones = jnp.ones((sub.shape[0],), jnp.float32)
    if method == "era":
        full_agg = agg.weighted_era(p, w, 0.1)
        sub_agg = agg.weighted_era(sub, ones, 0.1)
    else:
        full_agg = agg.weighted_sa(p, w)
        sub_agg = agg.weighted_sa(sub, ones)
    np.testing.assert_array_equal(np.asarray(full_agg), np.asarray(sub_agg))


@given(probs_and_mask(), st.floats(0.1, 1.0),
       st.integers(0, 5), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_staleness_decay_only_shrinks_weights(pm, decay, max_stale, seed):
    _, mask = pm
    K = mask.shape[0]
    stale = jax.random.randint(jax.random.PRNGKey(seed), (K,), 0,
                               max_stale + 1)
    m = jnp.asarray(mask, jnp.float32)
    w = agg.participation_weights(m, stale, decay)
    assert np.all(np.asarray(w) <= np.asarray(m) + 1e-9)
    assert np.all(np.asarray(w)[~mask] == 0.0)
    # decay == 1.0 is exactly "staleness ignored"
    np.testing.assert_array_equal(
        np.asarray(agg.participation_weights(m, stale, 1.0)), np.asarray(m))
    # decay == 0 with an all-stale cohort would zero every participant:
    # the fallback returns the raw mask so a downstream normalizing
    # average never divides by a zero total
    all_stale = jnp.ones_like(m, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(agg.participation_weights(m, all_stale, 0.0)),
        np.asarray(m))


@given(probs_and_mask(), st.integers(0, 8))
@settings(**SETTINGS)
def test_active_indices_contract(pm, extra):
    """The sparse plane's gather indices: participants first in ascending
    client order, padding lanes distinct non-participants — so the scatter
    back never collides and padding results are select_clients-discarded."""
    _, mask = pm
    K = mask.shape[0]
    need = int(mask.sum())
    m = min(K, need + extra)
    idx = np.asarray(active_indices(jnp.asarray(mask, jnp.float32), m))
    assert idx.shape == (m,)
    assert len(np.unique(idx)) == m                       # no collisions
    np.testing.assert_array_equal(idx[:need], np.flatnonzero(mask))
    assert not mask[idx[need:]].any()                     # padding: absent


# ------------------------------------------------ O(m log K) cohort draws ---
@given(st.integers(1, 40), st.integers(1, 40), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_floyd_sample_contract(K, m, seed):
    """Floyd's O(m) draw: exactly min(m, K) ids, distinct, sorted, in
    range, and bitwise deterministic under a fixed seed."""
    ids = floyd_sample(np.random.default_rng(seed), K, m)
    assert ids.shape == (min(m, K),)
    assert len(np.unique(ids)) == ids.size
    assert np.all(np.diff(ids) > 0) if ids.size > 1 else True
    assert ids.min() >= 0 and ids.max() < K
    again = floyd_sample(np.random.default_rng(seed), K, m)
    np.testing.assert_array_equal(ids, again)


@st.composite
def availability_vec(draw, max_k=6):
    K = draw(st.integers(2, max_k))
    avail = draw(st.lists(st.floats(0.05, 1.0), min_size=K, max_size=K))
    return np.asarray(avail)


@given(availability_vec(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_availability_weighted_draw_frequencies_track_weights(avail, seed):
    """The satellite pin for the fixed availability sampler: candidate
    frequencies from the cached-CDF draw converge to the normalized
    availability weights (the old per-round O(K) `rng.choice(p=...)`'s
    distribution), and a fixed seed reproduces the draw bitwise."""
    pop = ClientPopulation.uniform(avail.shape[0])
    pop.availability = avail
    n = 4000
    ids = weighted_draw_ids(np.random.default_rng(seed), pop, n)
    freq = np.bincount(ids, minlength=avail.shape[0]) / n
    np.testing.assert_allclose(freq, avail / avail.sum(),
                               atol=4.0 / np.sqrt(n) + 0.02)
    np.testing.assert_array_equal(
        ids, weighted_draw_ids(np.random.default_rng(seed), pop, n))


@given(availability_vec(), st.floats(0.2, 1.0), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_cohort_available_contract(avail, fraction, seed):
    """The id-form availability sampler: sorted distinct ids, never more
    than the cohort size, never empty, and seed-deterministic."""
    pop = ClientPopulation.uniform(avail.shape[0])
    pop.availability = avail
    K = avail.shape[0]
    ids = cohort_available(np.random.default_rng(seed), pop, fraction)
    m = min(K, max(1, int(round(fraction * K))))
    assert 1 <= ids.size <= m
    assert len(np.unique(ids)) == ids.size
    assert np.all(np.diff(ids) > 0) if ids.size > 1 else True
    np.testing.assert_array_equal(
        ids, cohort_available(np.random.default_rng(seed), pop, fraction))


@st.composite
def scheduler_cfg(draw, max_k=12):
    K = draw(st.integers(2, max_k))
    fraction = draw(st.floats(0.05, 1.0))
    deadline = draw(st.one_of(st.none(), st.floats(0.5, 50.0)))
    straggler = draw(st.sampled_from(["drop", "admit"]))
    seed = draw(st.integers(0, 2**31 - 1))
    return K, fraction, deadline, straggler, seed


@given(scheduler_cfg(), st.integers(2, 8))
@settings(**SETTINGS)
def test_schedulers_never_exceed_active_budget(cfg, rounds):
    """The sparse-round contract the schedulers guarantee by construction:
    every emitted RoundPlan has at most ``active_budget`` participants —
    for sync drop/admit rounds under any deadline, and for buffered async
    (where the budget is exactly the buffer size M)."""
    K, fraction, deadline, straggler, seed = cfg
    pop = ClientPopulation.lognormal(seed % 1000, K, compute_sigma=0.8)
    sched = SyncScheduler(pop, fraction=fraction, deadline=deadline,
                          straggler=straggler)
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        plan = sched.next_round(rng, 1e4, 1e4)
        assert plan.mask.sum() <= sched.active_budget

    asched = AsyncBufferScheduler(pop, buffer_size=1 + seed % K,
                                  jitter_sigma=0.3)
    assert asched.active_budget == asched.buffer_size
    for _ in range(rounds):
        plan = asched.next_round(rng, 1e4, 1e4)
        assert plan.mask.sum() <= asched.active_budget


@st.composite
def latencies_and_deadline(draw, max_k=10):
    K = draw(st.integers(2, max_k))
    lat = draw(st.lists(st.floats(0.1, 100.0), min_size=K, max_size=K))
    deadline = draw(st.one_of(st.none(), st.floats(0.5, 120.0)))
    return np.asarray(lat), deadline


@given(latencies_and_deadline(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_sync_scheduler_invariants(ld, seed):
    """Full-participation sync round: the mask is exactly the on-deadline
    cohort (never empty), dropped == selected minus mask, and the round
    closes at max(surviving latency) capped by the deadline."""
    lat, deadline = ld
    inf = np.full_like(lat, np.inf)
    pop = ClientPopulation(lat, inf, inf, np.ones_like(lat))
    sched = SyncScheduler(pop, deadline=deadline)
    plan = sched.next_round(np.random.default_rng(seed), 0, 0)
    assert plan.mask.any()
    assert not (plan.mask & plan.dropped).any()
    assert plan.t_end >= plan.t_start
    if deadline is None:
        assert plan.mask.all() and not plan.dropped.any()
        assert np.isclose(plan.duration, lat.max())
    elif (lat <= deadline).any():
        np.testing.assert_array_equal(plan.mask, lat <= deadline)
        assert np.isclose(plan.duration,
                          min(deadline, lat[plan.mask].max())
                          if not plan.dropped.any() else deadline)
    else:
        # everyone missed: the single fastest client is force-kept
        assert plan.mask.sum() == 1 and plan.mask[np.argmin(lat)]
        assert np.isclose(plan.duration, lat.min())