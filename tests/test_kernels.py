"""Per-kernel validation: shape/dtype sweeps, allclose vs the pure-jnp
oracles in repro/kernels/ref.py (kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.distill_loss import distill_loss_fwd_pallas
from repro.kernels.era_sharpen import era_sharpen_pallas
from repro.kernels.ssd_chunk import ssd_chunk_pallas


@pytest.mark.parametrize("K,N,C", [(2, 8, 10), (10, 64, 46), (5, 16, 512),
                                   (3, 32, 151)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T", [0.1, 0.5, 1.0])
def test_era_sharpen_sweep(rng, K, N, C, dtype, T):
    p = jax.nn.softmax(jax.random.normal(rng, (K, N, C)), -1).astype(dtype)
    out = era_sharpen_pallas(p, T, interpret=True)
    exp = ref.era_sharpen_ref(p, T)
    np.testing.assert_allclose(out, exp, atol=5e-3 if dtype == jnp.bfloat16
                               else 1e-6)


def test_era_sharpen_op_blocks(rng):
    # N not divisible by default block: op must adapt
    p = jax.nn.softmax(jax.random.normal(rng, (4, 6, 33)), -1)
    out = ops.era_sharpen(p, 0.1)
    np.testing.assert_allclose(out, ref.era_sharpen_ref(p, 0.1), atol=1e-6)


@pytest.mark.parametrize("N,block_n", [(100, 8), (1, 8), (13, 8), (5, 4),
                                       (9, 16)])
def test_era_sharpen_nondivisible_rows(rng, N, block_n):
    # regression: N % block_n != 0 used to assert; the kernel now pads the
    # row axis and slices the tail back off
    p = jax.nn.softmax(jax.random.normal(rng, (3, N, 21)), -1)
    out = era_sharpen_pallas(p, 0.1, block_n=block_n, interpret=True)
    assert out.shape == (N, 21)
    np.testing.assert_allclose(out, ref.era_sharpen_ref(p, 0.1), atol=1e-6)


def test_era_sharpen_nondivisible_under_jit(rng):
    p = jax.nn.softmax(jax.random.normal(rng, (2, 100, 17)), -1)
    out = jax.jit(lambda x: era_sharpen_pallas(x, 0.1, interpret=True))(p)
    np.testing.assert_allclose(out, ref.era_sharpen_ref(p, 0.1), atol=1e-6)


@pytest.mark.parametrize("N", [1, 100, 1000])
def test_era_kernel_path_any_open_batch(rng, N):
    """Acceptance pin: era(use_kernel=True) handles open-batch sizes that
    don't divide its row block (1, 100, 1000 with block_n=8)."""
    from repro.core import aggregation as agg
    p = jax.nn.softmax(jax.random.normal(rng, (3, N, 17)), -1)
    np.testing.assert_allclose(agg.era(p, 0.1, use_kernel=True),
                               agg.era(p, 0.1), atol=1e-5)


def test_era_kernel_interpret_resolution(monkeypatch):
    """use_kernel=True must not silently interpret off-CPU: the default
    (interpret=None) resolves to interpret mode on CPU only."""
    from repro.kernels import era_sharpen as es
    assert es.resolve_interpret(True) is True
    assert es.resolve_interpret(False) is False
    monkeypatch.setattr(es.jax, "default_backend", lambda: "cpu")
    assert es.resolve_interpret(None) is True
    monkeypatch.setattr(es.jax, "default_backend", lambda: "tpu")
    assert es.resolve_interpret(None) is False


# ------------------------------------------------- weighted ERA kernel ------
@pytest.mark.parametrize("K,N,C", [(2, 8, 10), (10, 64, 46), (5, 16, 512),
                                   (3, 31, 151)])
@pytest.mark.parametrize("T", [0.1, 0.5])
def test_weighted_era_sharpen_sweep(rng, K, N, C, T):
    """The fused weighted mean+sharpen kernel vs the jnp reference, fp32
    tolerance, including a zero-weight row and a non-divisible N."""
    from repro.kernels.era_sharpen import weighted_era_sharpen_pallas
    k1, k2 = jax.random.split(rng)
    p = jax.nn.softmax(jax.random.normal(k1, (K, N, C)) * 2, -1)
    w = jax.random.uniform(k2, (K,)).at[0].set(0.0)
    w = w / jnp.sum(w)
    out = weighted_era_sharpen_pallas(p, w, T, interpret=True)
    np.testing.assert_allclose(out, ref.weighted_era_sharpen_ref(p, w, T),
                               atol=1e-6)
    mean = weighted_era_sharpen_pallas(p, w, sharpen=False, interpret=True)
    np.testing.assert_allclose(
        mean, ref.weighted_era_sharpen_ref(p, w, sharpen=False), atol=1e-6)


def test_weighted_era_zero_weight_client_contributes_exactly_nothing(rng):
    """Acceptance pin: a zero-weight (absent) client's logits must not
    perturb the aggregate by a single bit — even when they are garbage."""
    from repro.kernels.era_sharpen import weighted_era_sharpen_pallas
    p = jax.nn.softmax(jax.random.normal(rng, (4, 9, 12)), -1)
    w = jnp.array([0.0, 0.5, 0.5, 0.0])
    garbage = p.at[0].set(1e30).at[3].set(-1e30)
    a = weighted_era_sharpen_pallas(p, w, 0.1, interpret=True)
    b = weighted_era_sharpen_pallas(garbage, w, 0.1, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("N,block_n", [(100, 8), (1, 8), (13, 8), (9, 16)])
def test_weighted_era_nondivisible_rows(rng, N, block_n):
    from repro.kernels.era_sharpen import weighted_era_sharpen_pallas
    p = jax.nn.softmax(jax.random.normal(rng, (3, N, 21)), -1)
    w = jnp.array([0.2, 0.5, 0.3])
    out = weighted_era_sharpen_pallas(p, w, 0.1, block_n=block_n,
                                      interpret=True)
    assert out.shape == (N, 21)
    np.testing.assert_allclose(out, ref.weighted_era_sharpen_ref(p, w, 0.1),
                               atol=1e-6)


def test_aggregate_with_weights_routes_weighted_kernel(rng, monkeypatch):
    """Acceptance pin: aggregate(..., use_kernel=True) with weights must hit
    the fused weighted kernel (not the einsum+softmax fallback), and match
    it."""
    from repro.core import aggregation as agg
    calls = []
    orig = ops.weighted_era_sharpen_pallas
    monkeypatch.setattr(ops, "weighted_era_sharpen_pallas",
                        lambda *a, **k: calls.append(1) or orig(*a, **k))
    p = jax.nn.softmax(jax.random.normal(rng, (4, 8, 10)) * 2, -1)
    w = jnp.array([1.0, 2.0, 0.0, 1.0])
    for method in ("weighted_era", "era", "sa"):
        out = agg.aggregate(p, method, 0.1, weights=w, use_kernel=True,
                            interpret=True)
        exp = agg.aggregate(p, method, 0.1, weights=w)
        np.testing.assert_allclose(out, exp, atol=1e-6)
    assert len(calls) == 3
    # the LLM-shaped 4-D stack stays on the einsum path (kernel is 3-D)
    p4 = jax.nn.softmax(jax.random.normal(rng, (3, 2, 4, 8)), -1)
    out4 = agg.weighted_era(p4, jnp.ones((3,)), 0.1, use_kernel=True)
    np.testing.assert_allclose(out4, agg.weighted_era(p4, jnp.ones((3,)), 0.1),
                               atol=1e-6)
    assert len(calls) == 3


def test_masked_dsfl_round_uses_weighted_kernel(rng, monkeypatch):
    """DSFLAlgorithm(use_kernel=True): the masked (sim) round's aggregation
    routes through the fused weighted kernel."""
    import dataclasses
    from repro.core.algorithms import DSFLAlgorithm
    from repro.core.engine import FedEngine
    from repro.core.protocol import DSFLConfig
    from repro.data.pipeline import build_image_task
    from repro.models.smallnets import apply_tiny_mlp, init_tiny_mlp
    calls = []
    orig = ops.weighted_era_sharpen_pallas
    monkeypatch.setattr(ops, "weighted_era_sharpen_pallas",
                        lambda *a, **k: calls.append(1) or orig(*a, **k))
    task = build_image_task(seed=0, K=4, n_private=80, n_open=40, n_test=20,
                            distribution="non_iid")
    hp = DSFLConfig(rounds=1, local_epochs=1, distill_epochs=1, batch_size=20,
                    open_batch=20, aggregation="era")
    algo = DSFLAlgorithm(apply_tiny_mlp, hp, use_kernel=True)
    eng = FedEngine(algo)
    mask = jnp.array([1.0, 0.0, 1.0, 1.0])
    eng.on_ctx = lambda r, ctx: dataclasses.replace(ctx, mask=mask)
    eng.run(eng.init(lambda k: init_tiny_mlp(k), task), task, rounds=1)
    assert calls, "masked round fell back to einsum+softmax"
    # absent client still gets exactly zero aggregation weight
    assert float(eng.last_metrics["agg_weights"][1]) == 0.0


def test_weighted_era_all_zero_weights_fall_back_to_uniform(rng):
    """All-zero reliability weights must degrade to plain ERA (uniform
    weights), not sharpen a zero mean into a uniform teacher."""
    from repro.core import aggregation as agg
    p = jax.nn.softmax(jax.random.normal(rng, (4, 8, 10)) * 2, -1)
    out = agg.weighted_era(p, jnp.zeros((4,)), 0.1)
    np.testing.assert_allclose(out, agg.era(p, 0.1), atol=1e-5)
    # and NOT the sharpened-zero-mean (exactly uniform) failure mode
    assert float(jnp.max(jnp.abs(np.asarray(out) - 1.0 / p.shape[-1]))) > 0.1


@pytest.mark.parametrize("N,V,bn,bv", [(32, 128, 8, 32), (64, 1024, 16, 256),
                                       (128, 512, 128, 512), (8, 64, 8, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_distill_loss_sweep(rng, N, V, bn, bv, dtype):
    k1, k2 = jax.random.split(rng)
    z = (jax.random.normal(k1, (N, V)) * 4).astype(dtype)
    t = jax.nn.softmax(jax.random.normal(k2, (N, V)), -1).astype(dtype)
    losses, logz = distill_loss_fwd_pallas(z, t, block_n=bn, block_v=bv,
                                           interpret=True)
    exp = ref.distill_loss_ref(z, t)
    atol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(losses, exp, atol=atol, rtol=1e-3)


def test_distill_loss_grad_matches_ref(rng):
    k1, k2 = jax.random.split(rng)
    z = jax.random.normal(k1, (64, 256)) * 3
    t = jax.nn.softmax(jax.random.normal(k2, (64, 256)), -1)
    g = jax.grad(lambda z_: ops.distill_loss(z_, t))(z)
    ge = ref.distill_loss_grad_ref(z, t, jnp.float32(1.0))
    np.testing.assert_allclose(g, ge, atol=1e-6)


def test_distill_loss_grad_matches_autodiff_of_ref(rng):
    k1, k2 = jax.random.split(rng)
    z = jax.random.normal(k1, (32, 96)) * 2
    t = jax.nn.softmax(jax.random.normal(k2, (32, 96)), -1)
    g_kernel = jax.grad(lambda z_: ops.distill_loss(z_, t))(z)
    g_auto = jax.grad(lambda z_: jnp.mean(ref.distill_loss_ref(z_, t)))(z)
    np.testing.assert_allclose(g_kernel, g_auto, atol=1e-5)


@pytest.mark.parametrize("M,Q,H,P,G,N", [
    (2, 8, 4, 8, 1, 8), (3, 16, 4, 8, 2, 8), (1, 32, 8, 16, 4, 16),
    (4, 16, 6, 8, 3, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_chunk_sweep(rng, M, Q, H, P, G, N, dtype):
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (M, Q, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (M, Q, H))).astype(dtype)
    dA = (-dt * 0.3).astype(dtype)
    B = jax.random.normal(ks[2], (M, Q, G, N), dtype)
    C = jax.random.normal(ks[3], (M, Q, G, N), dtype)
    y = ssd_chunk_pallas(x, dt, dA, B, C, interpret=True)
    exp = ref.ssd_chunk_ref(x, dt, dA, B, C)
    np.testing.assert_allclose(y, exp, atol=1e-4, rtol=1e-4)


def test_ssd_kernel_inside_mamba(rng):
    from repro.models.base import ModelConfig
    from repro.models.ssm import init_mamba, mamba_forward
    cfg = ModelConfig(name="s", arch_type="ssm", n_layers=2, d_model=64,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab=97, ssm_state=16,
                      ssm_head_dim=16, ssm_chunk=8, dtype="float32")
    p = init_mamba(rng, cfg)
    x = jax.random.normal(rng, (2, 16, 64))
    y_ref = mamba_forward(p, cfg, x)
    y_ker = mamba_forward(p, cfg, x, kernel_fn=ops.ssd_chunk)
    np.testing.assert_allclose(y_ref, y_ker, atol=1e-5)
