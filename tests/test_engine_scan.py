"""Compiled multi-round execution: `FedEngine.run(chunk_rounds=k)` must be
*bitwise* identical to the per-round reference loop — same key stream, same
state, same history — for every algorithm, under partial-participation
plans, across checkpoint/resume, and for any factorization of the round
range into chunks (hypothesis).  Also pins the scan-based RNG fast-forward
and the (state, ctx)-treedef-keyed jit cache (the stale `in_shardings`
landmine)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import (BatchCtx, DSFLAlgorithm, FDAlgorithm,
                                   FDConfig, FedAvgAlgorithm, FedAvgConfig)
from repro.core.engine import FedEngine, _fast_forward_key, make_eval_fn
from repro.core.protocol import DSFLConfig
from repro.data.pipeline import build_image_task
from repro.models.smallnets import apply_tiny_mlp, init_tiny_mlp
from repro.sim import ClientPopulation, SimRunner, SyncScheduler

K = 4
R = 6
HP = DSFLConfig(rounds=R, local_epochs=1, distill_epochs=1, batch_size=20,
                open_batch=40, aggregation="era")


@pytest.fixture(scope="module")
def task():
    return build_image_task(seed=0, K=K, n_private=160, n_open=80, n_test=40,
                            distribution="non_iid")


def _init(k):
    return init_tiny_mlp(k)


def _assert_states_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _run(algo, task, rounds=R, chunk=1, weights=(), ev=None, log_every=1,
         ctx_plan=None, active_budget=None, overlap=False):
    eng = FedEngine(algo, ev)
    state = eng.run(eng.init(_init, task), task, rounds=rounds,
                    weights=weights, log_every=log_every,
                    chunk_rounds=chunk, ctx_plan=ctx_plan,
                    active_budget=active_budget, overlap=overlap)
    return eng, state


# ------------------------------------------------------------- scan parity --
def _algo(kind, task):
    if kind.startswith("dsfl"):
        hp = dataclasses.replace(HP, aggregation=kind.split("_", 1)[1])
        return DSFLAlgorithm(apply_tiny_mlp, hp)
    if kind == "fd":
        return FDAlgorithm(apply_tiny_mlp,
                           FDConfig(rounds=R, local_epochs=1, batch_size=20,
                                    gamma=0.1, n_classes=task.n_classes))
    return FedAvgAlgorithm(apply_tiny_mlp,
                           FedAvgConfig(rounds=R, local_epochs=1,
                                        batch_size=20))


@pytest.mark.parametrize("kind", ["dsfl_sa", "dsfl_era", "dsfl_weighted_era",
                                  "fd", "fedavg"])
@pytest.mark.parametrize("chunk", [2, 3, 8])
def test_scan_is_bitwise_identical_to_loop(task, kind, chunk):
    """The tentpole pin: folding k rounds into one lax.scan changes nothing
    — not the final state's bits, not a single history float."""
    weights = jnp.ones((K,)) if kind == "fedavg" else ()
    e1, s1 = _run(_algo(kind, task), task, weights=weights)
    e2, s2 = _run(_algo(kind, task), task, chunk=chunk, weights=weights)
    _assert_states_equal(s1, s2)
    assert e1.history == e2.history
    assert e2.rounds_done == R


def test_scan_parity_with_eval_and_log_every(task):
    """Chunk boundaries snap to log_every so each eval sees the exact
    log-point state: history (incl. test accuracy) must match bitwise."""
    ev = make_eval_fn(apply_tiny_mlp, task.x_test, task.y_test)
    e1, s1 = _run(DSFLAlgorithm(apply_tiny_mlp, HP), task, ev=ev,
                  log_every=2)
    e2, s2 = _run(DSFLAlgorithm(apply_tiny_mlp, HP), task, ev=ev,
                  log_every=2, chunk=4)
    _assert_states_equal(s1, s2)
    assert e1.history == e2.history
    assert all("test_acc" in h for h in e2.history)


def test_scan_parity_under_ctx_plan_mask(task):
    """A pre-built (rounds, K) participation plan rides through the scan as
    per-step ctx inputs — identical to slicing it round-by-round."""
    mask = np.ones((R, K), np.float32)
    mask[1] = [1, 0, 1, 0]
    mask[4] = [0, 1, 1, 1]
    stale = np.zeros((R, K), np.int32)
    stale[4] = [0, 2, 0, 1]
    plan = {"mask": jnp.asarray(mask), "stale": jnp.asarray(stale)}
    e1, s1 = _run(DSFLAlgorithm(apply_tiny_mlp, HP), task, ctx_plan=plan)
    e2, s2 = _run(DSFLAlgorithm(apply_tiny_mlp, HP), task, ctx_plan=plan,
                  chunk=3)
    _assert_states_equal(s1, s2)
    assert e1.history == e2.history


def test_sim_sync_masked_chunked_run_is_bitwise(task):
    """Acceptance pin: a masked `SimRunner` sync-scheduler run (partial
    participation + deadline + admitted stragglers) chunked through the
    scan equals the per-round sim bitwise — state, engine history, sim
    ledger."""
    def make(chunk):
        eng = FedEngine(DSFLAlgorithm(apply_tiny_mlp, HP))
        pop = ClientPopulation.lognormal(3, K, compute_sigma=0.8)
        sched = SyncScheduler(pop, fraction=0.5, deadline=4.0,
                              straggler="admit")
        runner = SimRunner(eng, sched, seed=0)
        state = runner.run(eng.init(_init, task), task, rounds=R,
                           chunk_rounds=chunk)
        return runner, state

    r1, s1 = make(1)
    for chunk in (2, 4):
        r2, s2 = make(chunk)
        _assert_states_equal(s1, s2)
        assert r1.engine.history == r2.engine.history
        assert r1.history.records == r2.history.records
        assert r1.cum_bytes == r2.cum_bytes


def test_resume_across_chunk_boundary(task, tmp_path):
    """save -> load -> chunked run must continue the exact key stream: a
    checkpoint taken mid-stream (not on a chunk boundary of the resumed
    run) yields the same bits as the uninterrupted chunked run."""
    algo = DSFLAlgorithm(apply_tiny_mlp, HP)
    full, s_full = _run(algo, task, chunk=4)

    first = FedEngine(algo)
    mid = first.run(first.init(_init, task), task, rounds=3, chunk_rounds=2)
    path = os.path.join(tmp_path, "mid.msgpack")
    first.save_state(path, mid)

    second = FedEngine(algo)
    restored = second.load_state(path, algo.init(jax.random.PRNGKey(0),
                                                 _init, task))
    assert second.rounds_done == 3
    s_res = second.run(restored, task, rounds=R - 3, chunk_rounds=4)
    _assert_states_equal(s_full, s_res)
    assert second.history == full.history


def test_ctx_plan_shorter_than_rounds_raises(task):
    """A too-short plan must fail loudly on both paths (jnp's clamped
    indexing would silently reuse the last row on the loop path)."""
    plan = {"mask": jnp.ones((R - 1, K), jnp.float32)}
    for chunk in (1, 3):
        eng = FedEngine(DSFLAlgorithm(apply_tiny_mlp, HP))
        state = eng.init(_init, task)
        with pytest.raises(ValueError, match="ctx_plan"):
            eng.run(state, task, rounds=R, chunk_rounds=chunk, ctx_plan=plan)


def test_chunk_with_eval_and_default_log_every_warns(task):
    """eval_fn + log_every < chunk silently defeats the fusion; the engine
    says so."""
    ev = make_eval_fn(apply_tiny_mlp, task.x_test, task.y_test)
    eng = FedEngine(DSFLAlgorithm(apply_tiny_mlp, HP), ev)
    state = eng.init(_init, task)
    with pytest.warns(UserWarning, match="log_every"):
        eng.run(state, task, rounds=2, chunk_rounds=2)


# -------------------------------------------------- chunking invariance -----
def test_chunk_factorization_invariance_hypothesis(task):
    """Property: ANY factorization of run(rounds=R) into chunk_rounds
    segments — mixed chunk sizes, interleaved per-round calls, a
    save/load/resume at an arbitrary boundary — produces the identical
    final state and history."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st

    algo = DSFLAlgorithm(apply_tiny_mlp, HP)
    ref_eng, ref_state = _run(algo, task)
    ref_leaves = [np.asarray(l) for l in jax.tree.leaves(ref_state)]
    eng = FedEngine(algo)   # one engine: its jit caches persist across runs

    @st.composite
    def segmentations(draw):
        segs, left = [], R
        while left > 0:
            n = draw(st.integers(1, left))
            segs.append((n, draw(st.integers(1, 8))))   # (rounds, chunk)
            left -= n
        return segs

    @given(segmentations(), st.data())
    @settings(deadline=None, max_examples=8,
              suppress_health_check=[HealthCheck.too_slow])
    def check(segs, data):
        import tempfile
        state = eng.init(_init, task)
        ckpt_at = data.draw(st.integers(0, len(segs) - 1))
        for j, (n, chunk) in enumerate(segs):
            state = eng.run(state, task, rounds=n, chunk_rounds=chunk)
            if j == ckpt_at:
                with tempfile.TemporaryDirectory() as d:
                    path = os.path.join(d, "seg.msgpack")
                    eng.save_state(path, state)
                    state = eng.load_state(path, state)
        assert eng.rounds_done == R
        for a, b in zip(ref_leaves, jax.tree.leaves(state)):
            np.testing.assert_array_equal(a, np.asarray(b))
        assert eng.history == ref_eng.history

    check()


# -------------------------------------------- participation-sparse rounds ---
def _mask_plan(seed, rounds=R, k=K, p=0.4):
    """A random (rounds, K) participation plan (>=1 participant per round)
    with staleness on the participants; returns (plan, max popcount)."""
    rs = np.random.default_rng(seed)
    mask = rs.random((rounds, k)) < p
    for r in range(rounds):
        if not mask[r].any():
            mask[r, rs.integers(k)] = True
    stale = rs.integers(0, 3, (rounds, k)) * mask
    plan = {"mask": jnp.asarray(mask, jnp.float32),
            "stale": jnp.asarray(stale, jnp.int32)}
    return plan, int(mask.sum(1).max())


@pytest.mark.parametrize("kind", ["dsfl_sa", "dsfl_era", "dsfl_weighted_era",
                                  "fd", "fedavg"])
def test_sparse_round_bitwise_identical_to_dense_masked(task, kind):
    """The tentpole pin: computing only the <= m active client lanes
    (gather -> update/predict/distill -> scatter) changes nothing — not the
    final state's bits, not a single history float — on the loop path and
    through the compiled scan."""
    plan, need = _mask_plan(3)
    weights = jnp.ones((K,)) if kind == "fedavg" else ()
    e1, s1 = _run(_algo(kind, task), task, weights=weights, ctx_plan=plan)
    for budget, chunk in ((need, 1), (need, 3), (min(K - 1, need + 1), 2)):
        eng = FedEngine(_algo(kind, task))
        s2 = eng.run(eng.init(_init, task), task, rounds=R, weights=weights,
                     ctx_plan=plan, chunk_rounds=chunk, active_budget=budget)
        _assert_states_equal(s1, s2)
        assert e1.history == eng.history


def test_sparse_resume_across_chunk_boundary(task, tmp_path):
    """save -> load -> sparse chunked run continues the exact key stream:
    a mid-stream checkpoint of a sparse run resumes bitwise onto the
    uninterrupted dense masked run."""
    plan, need = _mask_plan(5)
    algo = DSFLAlgorithm(apply_tiny_mlp, HP)
    full, s_full = _run(algo, task, ctx_plan=plan)

    first = FedEngine(algo)
    mid = first.run(first.init(_init, task), task, rounds=3, chunk_rounds=2,
                    ctx_plan={f: v[:3] for f, v in plan.items()},
                    active_budget=need)
    path = os.path.join(tmp_path, "sparse_mid.msgpack")
    first.save_state(path, mid)

    second = FedEngine(algo)
    restored = second.load_state(path, algo.init(jax.random.PRNGKey(0),
                                                 _init, task))
    s_res = second.run(restored, task, rounds=R - 3, chunk_rounds=4,
                       ctx_plan={f: v[3:] for f, v in plan.items()},
                       active_budget=need)
    _assert_states_equal(s_full, s_res)
    assert second.history == full.history


def test_sparse_round_hypothesis_any_mask_stale_budget(task):
    """Property: for ANY participation plan, staleness vector and budget
    m >= popcount(mask), the sparse round is bitwise identical to the dense
    masked round — including through a save/load/resume at an arbitrary
    chunk boundary."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st

    algo = DSFLAlgorithm(apply_tiny_mlp, HP)
    dense_eng = FedEngine(algo)     # shared jit caches across examples
    sparse_eng = FedEngine(algo)

    @given(st.integers(0, 2**31 - 1), st.data())
    @settings(deadline=None, max_examples=6,
              suppress_health_check=[HealthCheck.too_slow])
    def check(seed, data):
        import tempfile
        plan, need = _mask_plan(seed)
        budget = data.draw(st.integers(need, K), label="budget")
        chunk = data.draw(st.integers(1, 4), label="chunk")
        cut = data.draw(st.integers(1, R - 1), label="resume_at")
        s1 = dense_eng.run(dense_eng.init(_init, task), task, rounds=R,
                           ctx_plan=plan)
        state = sparse_eng.run(sparse_eng.init(_init, task), task,
                               rounds=cut, chunk_rounds=chunk,
                               ctx_plan={f: v[:cut] for f, v in plan.items()},
                               active_budget=budget)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "cut.msgpack")
            sparse_eng.save_state(path, state)
            state = sparse_eng.load_state(path, state)
        s2 = sparse_eng.run(state, task, rounds=R - cut, chunk_rounds=chunk,
                            ctx_plan={f: v[cut:] for f, v in plan.items()},
                            active_budget=budget)
        _assert_states_equal(s1, s2)
        assert dense_eng.history == sparse_eng.history

    check()


def test_sim_runner_auto_budget_is_bitwise_and_sparse(task):
    """`SimRunner` derives the budget from the scheduler (`"auto"`): a
    25%-participation sync fleet runs the sparse plane and matches the
    forced-dense run bitwise — state, engine history, sim ledger."""
    K8, R8 = 8, 4
    task8 = build_image_task(seed=1, K=K8, n_private=160, n_open=80,
                             n_test=40, distribution="non_iid")

    def make(active_budget):
        eng = FedEngine(DSFLAlgorithm(apply_tiny_mlp, HP))
        pop = ClientPopulation.lognormal(3, K8, compute_sigma=0.8)
        sched = SyncScheduler(pop, fraction=0.25, straggler="drop")
        assert sched.active_budget == 2
        runner = SimRunner(eng, sched, seed=0)
        state = runner.run(eng.init(_init, task8), task8, rounds=R8,
                           active_budget=active_budget)
        return runner, state

    r1, s1 = make(None)          # forced dense masked
    r2, s2 = make("auto")        # sparse, budget from the scheduler
    _assert_states_equal(s1, s2)
    assert r1.engine.history == r2.engine.history
    assert r1.history.records == r2.history.records
    # the budget actually reached the jitted round: active_budget is ctx
    # *metadata*, so the sparse engine's cache keys (treedefs) must differ
    # from the dense engine's — identical keys would mean the budget was
    # silently dropped before the jit
    assert set(r2.engine._round_cache) != set(r1.engine._round_cache)


def test_sparse_plan_contract_enforced_loudly(task):
    """`run(active_budget=...)` rejects plans that break the sparse-round
    contract: a zero-participant round (its aggregation falls back to
    uniform-over-K, needing uploads the sparse plane skips) or a round
    with more participants than the budget (those clients would silently
    keep stale state while still carrying aggregation weight)."""
    mask = np.ones((R, K), np.float32)
    mask[1] = [1, 1, 1, 0]                   # 3 participants at round 2
    plan = {"mask": jnp.asarray(mask)}
    empty = {"mask": jnp.asarray(mask).at[2].set(0.0)}
    for bad, budget in ((empty, K - 1), (plan, 2)):
        eng = FedEngine(DSFLAlgorithm(apply_tiny_mlp, HP))
        state = eng.init(_init, task)
        with pytest.raises(ValueError, match="participants"):
            eng.run(state, task, rounds=R, ctx_plan=bad,
                    active_budget=budget)


def test_sim_runner_rejects_too_small_budget(task):
    """An explicit budget below the scheduled participant count must fail
    loudly — the sparse round would silently skip weighted clients."""
    K8 = 8
    task8 = build_image_task(seed=1, K=K8, n_private=160, n_open=80,
                             n_test=40, distribution="non_iid")
    eng = FedEngine(DSFLAlgorithm(apply_tiny_mlp, HP))
    pop = ClientPopulation.lognormal(3, K8)
    runner = SimRunner(eng, SyncScheduler(pop, fraction=0.5,
                                          straggler="drop"), seed=0)
    with pytest.raises(ValueError, match="active_budget"):
        runner.run(eng.init(_init, task8), task8, rounds=1, active_budget=1)


# ------------------------------------------------- pipelined (overlap) ------
@pytest.mark.parametrize("kind", ["dsfl_sa", "dsfl_era", "dsfl_weighted_era"])
@pytest.mark.parametrize("chunk", [2, 3, 8])
def test_overlap_is_bitwise_identical_to_sequential(task, kind, chunk):
    """The tentpole pin: software-pipelining the chunk (round r+1's
    exchange issued before round r's compute retires) changes nothing —
    not the final state's bits, not a single history float."""
    e1, s1 = _run(_algo(kind, task), task, chunk=chunk)
    e2, s2 = _run(_algo(kind, task), task, chunk=chunk, overlap=True)
    _assert_states_equal(s1, s2)
    assert e1.history == e2.history


def test_overlap_parity_masked_and_sparse(task):
    """The pipelined schedule composes with the participation planes: the
    dense-masked and sparse-budget runs stay bitwise under overlap."""
    plan, need = _mask_plan(3)
    for budget in (None, need):
        e1, s1 = _run(DSFLAlgorithm(apply_tiny_mlp, HP), task, chunk=3,
                      ctx_plan=plan, active_budget=budget)
        e2, s2 = _run(DSFLAlgorithm(apply_tiny_mlp, HP), task, chunk=3,
                      ctx_plan=plan, active_budget=budget, overlap=True)
        _assert_states_equal(s1, s2)
        assert e1.history == e2.history


def test_overlap_requires_round_start():
    """Algorithms without the round_start/round_finish split must fail
    loudly rather than silently running the sequential schedule."""
    algo = FedAvgAlgorithm(apply_tiny_mlp,
                           FedAvgConfig(rounds=1, local_epochs=1,
                                        batch_size=20))
    eng = FedEngine(algo)
    task1 = build_image_task(seed=0, K=K, n_private=160, n_open=80,
                             n_test=40, distribution="non_iid")
    state = eng.init(_init, task1)
    with pytest.raises(ValueError, match="round_start"):
        eng.run(state, task1, rounds=2, chunk_rounds=2, weights=jnp.ones(K),
                overlap=True)


def test_overlap_on_loop_path_warns_and_matches(task):
    """chunk_rounds=1 has no scan to pipeline: the engine says so and runs
    the (bitwise identical) sequential loop."""
    e1, s1 = _run(DSFLAlgorithm(apply_tiny_mlp, HP), task)
    eng = FedEngine(DSFLAlgorithm(apply_tiny_mlp, HP))
    state = eng.init(_init, task)
    with pytest.warns(UserWarning, match="overlap"):
        s2 = eng.run(state, task, rounds=R, overlap=True)
    _assert_states_equal(s1, s2)
    assert e1.history == eng.history


def test_overlap_factorization_and_resume_hypothesis(task):
    """Property: ANY factorization of the round range into pipelined and
    sequential chunks — mixed chunk sizes, mixed overlap toggles, a
    save/load/resume at an arbitrary chunk boundary — produces the
    identical final state and history."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st

    algo = DSFLAlgorithm(apply_tiny_mlp, HP)
    ref_eng, ref_state = _run(algo, task)
    ref_leaves = [np.asarray(l) for l in jax.tree.leaves(ref_state)]
    eng = FedEngine(algo)   # one engine: its jit caches persist across runs

    @st.composite
    def segmentations(draw):
        segs, left = [], R
        while left > 0:
            n = draw(st.integers(1, left))
            # overlap only on the scan path (chunk >= 2): the loop
            # fallback warns, which @given would surface as noise
            segs.append((n, draw(st.integers(2, 8)),
                         draw(st.booleans())))   # (rounds, chunk, overlap)
            left -= n
        return segs

    @given(segmentations(), st.data())
    @settings(deadline=None, max_examples=8,
              suppress_health_check=[HealthCheck.too_slow])
    def check(segs, data):
        import tempfile
        state = eng.init(_init, task)
        ckpt_at = data.draw(st.integers(0, len(segs) - 1))
        for j, (n, chunk, overlap) in enumerate(segs):
            state = eng.run(state, task, rounds=n, chunk_rounds=chunk,
                            overlap=overlap)
            if j == ckpt_at:
                with tempfile.TemporaryDirectory() as d:
                    path = os.path.join(d, "seg.msgpack")
                    eng.save_state(path, state)
                    state = eng.load_state(path, state)
        assert eng.rounds_done == R
        for a, b in zip(ref_leaves, jax.tree.leaves(state)):
            np.testing.assert_array_equal(a, np.asarray(b))
        assert eng.history == ref_eng.history

    check()


def test_overlap_toggle_adds_no_steady_state_recompiles(task):
    """JitCacheWatch pin: once both schedules are warm, toggling
    ``overlap`` per run switches between two cached chunk programs —
    zero new compiles, zero retraces."""
    from repro.obs import JitCacheWatch

    algo = DSFLAlgorithm(apply_tiny_mlp, HP)
    eng = FedEngine(algo)
    with JitCacheWatch() as watch:
        for overlap in (False, True):        # warm both chunk programs
            state = eng.init(_init, task)
            eng.run(state, task, rounds=R, chunk_rounds=3, overlap=overlap)
        watch.mark()
        for overlap in (False, True, False, True):
            state = eng.init(_init, task)
            eng.run(state, task, rounds=R, chunk_rounds=3, overlap=overlap)
        watch.assert_no_new_compiles("after overlap toggle warmup")


# ------------------------------------------------------ RNG fast-forward ----
def test_fast_forward_key_matches_host_loop_bitwise(rng):
    """The satellite pin: the jitted device-side fast-forward produces
    bitwise the key the seed engine's host loop would."""
    for n in (0, 1, 7, 500):
        expect = rng
        for _ in range(n):
            expect, _, _ = jax.random.split(expect, 3)
        got = _fast_forward_key(rng, n)
        np.testing.assert_array_equal(np.asarray(expect), np.asarray(got))


# ----------------------------------------------------- stale jit cache ------
@dataclasses.dataclass(frozen=True)
class _ShardedFedAvg(FedAvgAlgorithm):
    """FedAvg exposing replicate-everything shardings, to drive the
    mesh-aware `in_shardings` jit on a 1-device mesh."""

    def shardings(self, mesh, state, ctx):
        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
        return (jax.tree.map(lambda _: rep, state),
                jax.tree.map(lambda _: rep, ctx))


def test_round_cache_rebuilds_when_ctx_structure_changes(task):
    """Regression: the jitted round (and its in_shardings) used to be built
    once from the *first* round's ctx treedef; an `on_ctx` hook flipping
    mask/stale from EMPTY to arrays then handed it a ctx it was never
    built for.  The cache is now keyed on the (state, ctx) tree structure
    and rebuilds on change."""
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("d",))
    algo = _ShardedFedAvg(apply_tiny_mlp,
                          FedAvgConfig(rounds=2, local_epochs=1,
                                       batch_size=20))
    eng = FedEngine(algo, mesh=mesh)
    state = eng.init(_init, task)
    state = eng.run(state, task, rounds=1)          # full participation
    mask = jnp.array([1.0, 0.0, 1.0, 1.0])
    eng.on_ctx = lambda r, ctx: dataclasses.replace(ctx, mask=mask)
    state = eng.run(state, task, rounds=1)          # ctx treedef changed
    assert float(eng.last_metrics["participants"]) == 3.0
    assert len(eng._round_cache) == 2               # one round per treedef


# ----------------------------------------------- cohort checkpoint/resume ---
def _cohort_runner(task, store_rng):
    from repro.core.cohort import ClientStore
    from repro.data.pipeline import ArrayProvider
    from repro.sim import CohortRunner

    algo = DSFLAlgorithm(apply_tiny_mlp, HP)
    eng = FedEngine(algo)
    pop = ClientPopulation.lognormal(3, K, compute_sigma=0.8)
    sched = SyncScheduler(pop, fraction=0.5, deadline=4.0, straggler="admit")
    store = ClientStore(
        lambda ids: algo.init_cohort(store_rng, _init, ids, K))
    return CohortRunner(engine=eng, scheduler=sched,
                        provider=ArrayProvider(task), store=store, seed=0)


def test_cohort_checkpoint_roundtrip_across_chunk_boundary(task, tmp_path):
    """Satellite pin: a `CohortRunner` checkpoint taken at a chunk boundary
    — engine state, host-side client store, scheduler books — resumes onto
    the uninterrupted run bitwise: server state, every stored client row,
    the sim ledger and the virtual clock."""
    rng0 = jax.random.PRNGKey(HP.seed)
    full = _cohort_runner(task, rng0)
    algo = full.engine.algo
    s_full = full.run(algo.init_server(rng0, _init), rounds=6,
                      chunk_rounds=2)

    first = _cohort_runner(task, rng0)
    mid = first.run(algo.init_server(rng0, _init), rounds=4, chunk_rounds=2)
    path = os.path.join(tmp_path, "cohort.msgpack")
    first.save_state(path, mid)
    assert os.path.exists(path + ".store")
    assert os.path.exists(path + ".sim.json")

    second = _cohort_runner(task, rng0)
    restored = second.load_state(path, mid)
    assert second.engine.rounds_done == 4
    assert second.scheduler.clock.now == first.scheduler.clock.now
    assert list(second.store.ids()) == list(first.store.ids())
    s_res = second.run(restored, rounds=2, chunk_rounds=2)

    _assert_states_equal(s_full.server, s_res.server)
    ids = full.store.ids()
    np.testing.assert_array_equal(ids, second.store.ids())
    _assert_states_equal(full.store.gather(ids), second.store.gather(ids))
    assert [h["t_cum"] for h in second.history.records] == \
        [h["t_cum"] for h in full.history.records]
    assert second.cum_bytes == full.cum_bytes
    assert full.engine.history == second.engine.history


def test_manual_round_override_still_wins(task):
    """`_round` stays a manual override slot (tests monkeypatch it); the
    treedef cache must not shadow it."""
    algo = FedAvgAlgorithm(apply_tiny_mlp,
                           FedAvgConfig(rounds=1, local_epochs=1,
                                        batch_size=20))
    eng = FedEngine(algo)
    state = algo.init_from(*_init(jax.random.PRNGKey(0)))
    eng._round = lambda s, c, k: (s, {"stub": 1.0})
    eng.run(state, task, rounds=1)
    assert eng.history[0]["stub"] == 1.0
