"""`repro.sim`: population/clock/scheduler unit behaviour, golden parity of
`SimRunner` against the plain engine under an idealized scheduler, masked
round semantics (absent clients untouched), and checkpoint/resume of the
virtual clock."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import DSFLAlgorithm, FedAvgAlgorithm, FedAvgConfig
from repro.core.engine import FedEngine, make_eval_fn
from repro.core.protocol import DSFLConfig
from repro.data.pipeline import build_image_task
from repro.sim import (AsyncBufferScheduler, ClientPopulation, SimRunner,
                       SyncScheduler, VirtualClock, sample_available,
                       sample_uniform)
from repro.models.smallnets import apply_mnist_cnn, init_mnist_cnn

K = 4


def _init(k):
    return init_mnist_cnn(k, image_hw=16, widths=(8, 16), fc=32)


@pytest.fixture(scope="module")
def task():
    return build_image_task(seed=0, K=K, n_private=320, n_open=160,
                            n_test=160, distribution="non_iid")


HP = DSFLConfig(rounds=2, local_epochs=1, distill_epochs=1, batch_size=40,
                open_batch=80, aggregation="era")


def _pop(latencies):
    """Population with unit links so latency == compute_time + up + down."""
    lat = np.asarray(latencies, float)
    inf = np.full_like(lat, np.inf)
    return ClientPopulation(lat, inf, inf, np.ones_like(lat))


# ------------------------------------------------------ population & clock ---
def test_latency_charges_all_three_legs():
    pop = ClientPopulation.uniform(3, compute_time=2.0, uplink=10.0,
                                   downlink=100.0)
    lat = pop.latency(up_bytes=50, down_bytes=200)
    np.testing.assert_allclose(lat, 200 / 100 + 2.0 + 50 / 10)


def test_lognormal_population_shapes_and_downlink_factor():
    pop = ClientPopulation.lognormal(0, 16, downlink_factor=7.0)
    assert pop.n_clients == 16
    np.testing.assert_allclose(pop.downlink, 7.0 * pop.uplink)
    assert np.all(pop.availability == 1.0)


def test_clock_refuses_to_run_backwards():
    with pytest.raises(ValueError):
        VirtualClock().advance(-1.0)


def test_sample_uniform_exact_cohort_size():
    rng = np.random.default_rng(0)
    pop = ClientPopulation.uniform(8)
    for frac, want in [(1.0, 8), (0.5, 4), (0.01, 1)]:
        mask = sample_uniform(rng, pop, frac)
        assert mask.sum() == want


def test_sample_available_falls_back_to_most_available():
    pop = ClientPopulation.uniform(3, availability=1e-12)
    pop.availability = np.array([1e-12, 1e-12, 2e-12])
    mask = sample_available(np.random.default_rng(0), pop)
    assert mask.sum() == 1 and mask[2]


# ---------------------------------------------------------------- schedulers -
def test_sync_round_waits_for_slowest_without_deadline():
    sched = SyncScheduler(_pop([1.0, 5.0, 2.0]))
    plan = sched.next_round(np.random.default_rng(0), 0, 0)
    assert plan.mask.all() and plan.duration == 5.0
    assert plan.staleness.sum() == 0 and not plan.dropped.any()
    assert sched.idealized


def test_sync_deadline_drops_stragglers():
    sched = SyncScheduler(_pop([1.0, 5.0, 2.0]), deadline=3.0)
    plan = sched.next_round(np.random.default_rng(0), 0, 0)
    np.testing.assert_array_equal(plan.mask, [True, False, True])
    np.testing.assert_array_equal(plan.dropped, [False, True, False])
    assert plan.duration == 3.0 and not sched.idealized


def test_sync_admit_late_joins_next_round_stale():
    sched = SyncScheduler(_pop([1.0, 5.0, 2.0]), deadline=3.0,
                          straggler="admit")
    first = sched.next_round(np.random.default_rng(0), 0, 0)
    assert not first.mask[1]
    second = sched.next_round(np.random.default_rng(1), 0, 0)
    assert second.mask[1] and second.staleness[1] == 1
    third = sched.next_round(np.random.default_rng(2), 0, 0)
    assert third.staleness[1] == 1        # re-dropped, re-admitted — not 2


def test_sync_all_past_deadline_keeps_fastest():
    sched = SyncScheduler(_pop([9.0, 5.0, 7.0]), deadline=1.0)
    plan = sched.next_round(np.random.default_rng(0), 0, 0)
    np.testing.assert_array_equal(plan.mask, [False, True, False])
    assert plan.duration == 5.0           # closed at the forced-kept client


def test_async_buffer_aggregates_m_earliest():
    sched = AsyncBufferScheduler(_pop([1.0, 10.0, 1.0]), buffer_size=2)
    p1 = sched.next_round(np.random.default_rng(0), 0, 0)
    np.testing.assert_array_equal(p1.mask, [True, False, True])
    assert p1.t_end == 1.0 and p1.staleness.sum() == 0
    # the fast pair laps the slow client, always freshly synced (their
    # labels come from the immediately-preceding aggregation: staleness 0)
    p2 = sched.next_round(np.random.default_rng(1), 0, 0)
    np.testing.assert_array_equal(p2.mask, [True, False, True])
    assert p2.t_end == 2.0 and list(p2.staleness[p2.mask]) == [0, 0]
    assert not sched.idealized


def test_async_slow_client_eventually_lands_with_large_staleness():
    sched = AsyncBufferScheduler(_pop([1.0, 3.5, 1.0]), buffer_size=2)
    stale_of_1 = []
    for r in range(4):
        plan = sched.next_round(np.random.default_rng(r), 0, 0)
        if plan.mask[1]:
            stale_of_1.append(int(plan.staleness[1]))
    assert stale_of_1 and stale_of_1[0] >= 2


# ------------------------------------------------------------ golden parity --
def test_idealized_simrunner_is_bitwise_identical_to_engine(task):
    """participation 1.0, no stragglers, uniform links: every SimRunner
    round must be the plain FedEngine round bit-for-bit (state and
    metrics), with the wallclock/byte ledger riding alongside."""
    algo = DSFLAlgorithm(apply_mnist_cnn, HP)
    ev = make_eval_fn(apply_mnist_cnn, task.x_test, task.y_test)

    plain = FedEngine(algo, ev)
    s0 = plain.run(plain.init(_init, task), task, rounds=2)

    eng = FedEngine(algo, ev)
    runner = SimRunner(eng, SyncScheduler(ClientPopulation.uniform(K)))
    s1 = runner.run(eng.init(_init, task), task, rounds=2)

    assert runner.scheduler.idealized
    assert plain.history == eng.history
    for a, b in zip(jax.tree.leaves(s0), jax.tree.leaves(s1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(runner.history) == 2
    t = runner.history.series("t_cum")
    assert t[1] > t[0] > 0
    up, down = eng.measured_leg_bytes(s1, task)
    assert runner.history[0]["cum_bytes"] == up * K + down


def test_masked_round_leaves_absent_clients_untouched(task):
    """mask [1,0,1,1]: client 1 must neither update nor distill — its
    params, model state and optimizer slots stay bitwise identical."""
    algo = DSFLAlgorithm(apply_mnist_cnn, HP)
    eng = FedEngine(algo)
    mask = jnp.array([1.0, 0.0, 1.0, 1.0])
    eng.on_ctx = lambda r, ctx: dataclasses.replace(ctx, mask=mask)
    state0 = eng.init(_init, task)
    state1 = eng.run(state0, task, rounds=1)
    for a, b in zip(jax.tree.leaves(state0.clients),
                    jax.tree.leaves(state1.clients)):
        np.testing.assert_array_equal(np.asarray(a)[1], np.asarray(b)[1])
    for a, b in zip(jax.tree.leaves(state0.clients.params),
                    jax.tree.leaves(state1.clients.params)):
        assert not np.array_equal(np.asarray(a)[0], np.asarray(b)[0])
    assert float(eng.last_metrics["participants"]) == 3.0
    # absent client got exactly zero aggregation weight
    assert float(eng.last_metrics["agg_weights"][1]) == 0.0


def test_masked_fedavg_average_ignores_absent_clients(task):
    """A participation mask must act exactly like zeroing those clients'
    Eq. 3 weights (the already-tested weights path), and differ from the
    full-participation average."""
    algo = FedAvgAlgorithm(apply_mnist_cnn,
                           FedAvgConfig(rounds=1, local_epochs=1,
                                        batch_size=40))
    mask = jnp.array([1.0, 1.0, 0.0, 0.0])

    eng = FedEngine(algo)
    eng.on_ctx = lambda r, ctx: dataclasses.replace(ctx, mask=mask)
    masked = eng.run(algo.init_from(*_init(jax.random.PRNGKey(7))), task,
                     rounds=1)

    eng2 = FedEngine(algo)
    zeroed = eng2.run(algo.init_from(*_init(jax.random.PRNGKey(7))), task,
                      rounds=1, weights=mask)
    for a, b in zip(jax.tree.leaves(masked.server),
                    jax.tree.leaves(zeroed.server)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    eng3 = FedEngine(algo)
    full = eng3.run(algo.init_from(*_init(jax.random.PRNGKey(7))), task,
                    rounds=1)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(masked.server),
                               jax.tree.leaves(full.server)))


def test_masked_fedavg_all_stale_zero_decay_stays_finite(task):
    """staleness_decay=0 + an all-stale cohort decays every participant's
    weight to zero; `participation_weights` must fall back to the raw mask
    (uniform over participants) instead of letting the Eq. 3 average divide
    by a zero total and NaN the global model."""
    algo = FedAvgAlgorithm(apply_mnist_cnn,
                           FedAvgConfig(rounds=1, local_epochs=1,
                                        batch_size=40, staleness_decay=0.0))
    mask = jnp.array([1.0, 1.0, 0.0, 0.0])
    stale = jnp.array([2, 1, 0, 0], jnp.int32)
    eng = FedEngine(algo)
    eng.on_ctx = lambda r, ctx: dataclasses.replace(ctx, mask=mask,
                                                    stale=stale)
    out = eng.run(algo.init_from(*_init(jax.random.PRNGKey(7))), task,
                  rounds=1)
    for leaf in jax.tree.leaves(out.server):
        assert np.isfinite(np.asarray(leaf)).all()


# -------------------------------------------------------- checkpoint/resume --
def _make_runner(task, tmp_seed=0):
    algo = DSFLAlgorithm(apply_mnist_cnn, HP)
    eng = FedEngine(algo)
    pop = ClientPopulation.lognormal(3, K, compute_sigma=0.8)
    sched = SyncScheduler(pop, fraction=0.5, deadline=4.0, straggler="admit")
    return SimRunner(eng, sched, seed=tmp_seed)


def test_simrunner_checkpoint_roundtrip_preserves_virtual_clock(task,
                                                                tmp_path):
    full = _make_runner(task)
    sf = full.run(full.engine.init(_init, task), task, rounds=4)

    first = _make_runner(task)
    mid = first.run(first.engine.init(_init, task), task, rounds=2)
    path = os.path.join(tmp_path, "sim.msgpack")
    first.save_state(path, mid)
    assert os.path.exists(path + ".sim.json")

    second = _make_runner(task)
    algo = second.engine.algo
    restored = second.load_state(path, algo.init(jax.random.PRNGKey(0),
                                                 _init, task))
    assert second.scheduler.clock.now == first.scheduler.clock.now
    assert second.cum_bytes == first.cum_bytes
    sr = second.run(restored, task, rounds=2)

    assert [h["t_cum"] for h in second.history] == \
        [h["t_cum"] for h in full.history]
    assert [h["participants"] for h in second.history] == \
        [h["participants"] for h in full.history]
    assert second.cum_bytes == full.cum_bytes
    for a, b in zip(jax.tree.leaves(sf), jax.tree.leaves(sr)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
