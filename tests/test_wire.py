"""Wire-format codecs: encode->decode round trips, and measured payload
bytes == `CommModel`'s analytic per-round bytes for every codec/algorithm
(the Table 1/2 cross-check, on real tensors)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import wire
from repro.core.algorithms import (DSFLAlgorithm, FDAlgorithm, FDConfig,
                                   FedAvgAlgorithm, FedAvgConfig)
from repro.core.comm import CommModel
from repro.core.engine import FedEngine
from repro.core.protocol import DSFLConfig
from repro.data.pipeline import build_image_task
from repro.models.base import param_count
from repro.models.smallnets import apply_mnist_cnn, init_mnist_cnn

K, N, C = 4, 80, 10


def _init(k):
    return init_mnist_cnn(k, image_hw=16, widths=(8, 16), fc=32)


@pytest.fixture(scope="module")
def task():
    return build_image_task(seed=0, K=K, n_private=320, n_open=N,
                            n_test=80, distribution="non_iid")


@pytest.fixture(scope="module")
def probs(rng):
    return jax.nn.softmax(jax.random.normal(rng, (N, C)), -1)


# ------------------------------------------------------------ round trips ----
def test_dense_f32_roundtrip_exact(probs):
    codec = wire.DenseF32Codec()
    out = codec.decode(codec.encode(probs))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(probs))


def test_fp16_roundtrip_within_half_precision(probs):
    codec = wire.FP16Codec()
    enc = codec.encode(probs)
    assert jax.tree.leaves(enc)[0].dtype == jnp.float16
    out = codec.decode(enc)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(probs), atol=5e-4)


def test_topk_roundtrip_identity_when_k_equals_C(probs):
    codec = wire.TopKCodec(k=C, n_classes=C)
    out = codec.decode(codec.encode(probs))
    np.testing.assert_allclose(np.asarray(out), np.asarray(probs), atol=1e-6)


def test_topk_decoded_is_renormalized_distribution(probs):
    codec = wire.TopKCodec(k=3, n_classes=C)
    out = codec.decode(codec.encode(probs))
    np.testing.assert_allclose(np.sum(np.asarray(out), -1), 1.0, atol=1e-5)
    # kept entries are the k largest, rescaled; dropped entries are zero
    assert int(np.count_nonzero(np.asarray(out)[0])) <= 3


def test_int8_roundtrip_within_half_step(probs):
    codec = wire.Int8Codec()
    enc = codec.encode(probs)
    assert enc["q"].dtype == jnp.uint8
    out = codec.decode(enc)
    assert out.dtype == jnp.float32
    half_step = float(enc["scale"]) / 2
    assert float(jnp.max(jnp.abs(out - probs))) <= half_step * 1.001


def test_asymmetric_codec_legs_differ(probs):
    codec = wire.AsymmetricCodec(up=wire.TopKCodec(k=3, n_classes=C),
                                 down=wire.FP16Codec())
    up = codec.encode_up(probs)
    down = codec.encode_down(probs)
    assert codec.payload_bytes(up) == N * 3 * 8       # k (value, index) pairs
    assert codec.payload_bytes(down) == N * C * 2     # dense fp16 broadcast
    # encode/decode alias the uplink leg (what K clients each send)
    np.testing.assert_array_equal(np.asarray(jax.tree.leaves(up)[0]),
                                  np.asarray(jax.tree.leaves(
                                      codec.encode(probs))[0]))
    # each leg round-trips through its own decode
    np.testing.assert_allclose(np.asarray(codec.decode_down(down)),
                               np.asarray(probs), atol=1e-3)
    assert int(np.count_nonzero(np.asarray(codec.decode_up(up))[0])) <= 3


def test_symmetric_codecs_have_equal_legs(probs):
    for codec in (wire.DenseF32Codec(), wire.FP16Codec(), wire.Int8Codec()):
        assert wire.nbytes(codec.encode_up(probs)) == \
            wire.nbytes(codec.encode_down(probs))


def test_codecs_encode_whole_pytrees(rng):
    tree = {"a": jax.random.normal(rng, (3, C)),
            "b": [jax.random.normal(rng, (2, 2, C))]}
    codec = wire.FP16Codec()
    out = codec.decode(codec.encode(tree))
    assert set(out) == {"a", "b"}
    assert out["a"].dtype == jnp.float32


# ----------------------------------------------- measured == analytic --------
def test_measured_equals_analytic_for_every_dsfl_codec(task):
    hp = DSFLConfig(rounds=1, local_epochs=1, distill_epochs=1, batch_size=40,
                    open_batch=N)
    algo = DSFLAlgorithm(apply_mnist_cnn, hp)
    key = jax.random.PRNGKey(0)
    state = algo.init(key, _init, task)
    cm = CommModel(K, C, 0, N)
    cases = [(wire.DenseF32Codec(), cm.dsfl_round()),
             (wire.FP16Codec(), cm.dsfl_fp16_round()),
             (wire.TopKCodec(k=5, n_classes=C), cm.dsfl_topk_round(5)),
             (wire.Int8Codec(), cm.dsfl_int8_round())]
    for codec, analytic in cases:
        eng = FedEngine(algo, codec=codec)
        assert eng.measured_round_bytes(state, task) == analytic, codec.name


def test_measured_leg_bytes_asymmetric(task):
    """Per-leg accounting: K top-k uplinks + 1 dense fp16 broadcast — each
    leg equal to its CommModel analytic per-payload number."""
    hp = DSFLConfig(rounds=1, local_epochs=1, distill_epochs=1, batch_size=40,
                    open_batch=N)
    algo = DSFLAlgorithm(apply_mnist_cnn, hp)
    state = algo.init(jax.random.PRNGKey(0), _init, task)
    cm = CommModel(K, C, 0, N)
    codec = wire.AsymmetricCodec(up=wire.TopKCodec(k=5, n_classes=C),
                                 down=wire.FP16Codec())
    eng = FedEngine(algo, codec=codec)
    up, down = eng.measured_leg_bytes(state, task)
    assert up == cm.dsfl_topk_round(5) // (K + 1)
    assert down == cm.dsfl_fp16_round() // (K + 1)
    assert eng.measured_round_bytes(state, task) == up * K + down


def test_measured_equals_analytic_fd(task):
    algo = FDAlgorithm(apply_mnist_cnn, FDConfig(rounds=1, n_classes=C))
    state = algo.init(jax.random.PRNGKey(0), _init, task)
    cm = CommModel(K, C, 0, N)
    assert FedEngine(algo).measured_round_bytes(state, task) == cm.fd_round()


def test_measured_equals_analytic_fedavg(task):
    algo = FedAvgAlgorithm(apply_mnist_cnn, FedAvgConfig(rounds=1))
    state = algo.init(jax.random.PRNGKey(0), _init, task)
    n_params = (param_count(state.server.params)
                + param_count(state.server.model_state))
    cm = CommModel(K, C, n_params, N)
    assert FedEngine(algo).measured_round_bytes(state, task) == cm.fl_round()


def test_payload_bytes_counts_encoded_not_decoded(probs):
    dense = wire.DenseF32Codec()
    half = wire.FP16Codec()
    topk = wire.TopKCodec(k=5, n_classes=C)
    d = dense.payload_bytes(dense.encode(probs))
    assert d == N * C * 4
    assert half.payload_bytes(half.encode(probs)) == d // 2
    assert topk.payload_bytes(topk.encode(probs)) == N * 5 * 8


def test_make_codec_registry():
    assert isinstance(wire.make_codec("dense_f32"), wire.DenseF32Codec)
    assert wire.make_codec("topk", k=7, n_classes=C).k == 7
    assert isinstance(wire.make_codec("int8"), wire.Int8Codec)
    asym = wire.make_codec("asym", up=wire.Int8Codec())
    assert isinstance(asym.up, wire.Int8Codec)
    assert isinstance(asym.down, wire.FP16Codec)
    with pytest.raises(KeyError):
        wire.make_codec("zstd")
