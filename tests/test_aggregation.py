"""Property-based tests (hypothesis) for the aggregation operators — the
system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import aggregation as agg
from repro.core.losses import entropy

SETTINGS = dict(deadline=None, max_examples=30,
                suppress_health_check=[HealthCheck.too_slow])


def probs_strategy(max_k=6, max_n=5, max_c=8):
    @st.composite
    def _build(draw):
        K = draw(st.integers(1, max_k))
        N = draw(st.integers(1, max_n))
        C = draw(st.integers(2, max_c))
        seed = draw(st.integers(0, 2**31 - 1))
        logits = jax.random.normal(jax.random.PRNGKey(seed), (K, N, C)) * 3
        return jax.nn.softmax(logits, -1)
    return _build()


@given(probs_strategy())
@settings(**SETTINGS)
def test_sa_is_valid_distribution(p):
    out = agg.sa(p)
    np.testing.assert_allclose(np.sum(out, -1), 1.0, atol=1e-5)
    assert np.all(np.asarray(out) >= 0)


@given(probs_strategy(), st.sampled_from([0.05, 0.1, 0.5]))
@settings(**SETTINGS)
def test_era_is_valid_distribution(p, T):
    out = agg.era(p, T)
    np.testing.assert_allclose(np.sum(out, -1), 1.0, atol=1e-5)
    assert np.all(np.asarray(out) >= 0)


@given(probs_strategy(), st.sampled_from([0.05, 0.1, 0.5]))
@settings(**SETTINGS)
def test_era_preserves_argmax_of_mean(p, T):
    """softmax is monotone: sharpening must not change the winning class."""
    mean = agg.sa(p)
    out = agg.era(p, T)
    np.testing.assert_array_equal(np.argmax(out, -1), np.argmax(mean, -1))


@given(probs_strategy())
@settings(**SETTINGS)
def test_era_reduces_entropy_at_paper_temperature(p):
    """The paper's claim (Fig. 4b): at T=0.1 the output entropy is GENERALLY
    lower than the input's.  Property testing found the two true boundaries
    (documented in EXPERIMENTS.md §Claims):
      (a) below the softmax floor an exactly one-hot mean gets *smoothed*
          (visible in the paper's own Fig. 4b as the crossover);
      (b) a bimodal mean (two clients in flat disagreement) keeps its two
          equal peaks — sharpening cannot break the tie and can raise H.
    The reduction holds whenever the mean has a dominant mode above the
    floor, which is the regime the paper operates in."""
    C = p.shape[-1]
    floor = np.asarray(entropy(
        agg.era(jax.nn.one_hot(jnp.zeros((1,), jnp.int32), C)[None], 0.1)))[0]
    mean = np.asarray(agg.sa(p))
    srt = np.sort(mean, axis=-1)
    dominant = (srt[..., -1] - srt[..., -2]) >= 0.15
    h_sa = np.asarray(entropy(agg.sa(p)))
    h_era = np.asarray(entropy(agg.era(p, 0.1)))
    hi = (h_sa > floor + 0.05) & dominant
    assert np.all(h_era[hi] <= h_sa[hi] + 1e-4)


@given(probs_strategy(max_k=5), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_aggregation_client_permutation_invariant(p, seed):
    perm = jax.random.permutation(jax.random.PRNGKey(seed), p.shape[0])
    np.testing.assert_allclose(agg.era(p, 0.1), agg.era(p[perm], 0.1),
                               atol=1e-5)


@given(probs_strategy(max_k=1))
@settings(**SETTINGS)
def test_sa_single_client_identity(p):
    np.testing.assert_allclose(agg.sa(p), p[0], atol=1e-6)


@given(probs_strategy(), st.integers(1, 4))
@settings(**SETTINGS)
def test_topk_roundtrip_keeps_topk_mass(p, k):
    k = min(k, p.shape[-1])
    v, i = agg.topk_compress(p[0], k)
    dense = agg.topk_decompress(v, i, p.shape[-1])
    np.testing.assert_allclose(np.sum(dense, -1), 1.0, atol=1e-5)
    # the surviving support must be the true top-k of the input
    true_topk = np.argsort(-np.asarray(p[0]), axis=-1)[..., :k]
    assert np.all(np.sort(np.asarray(i), -1) == np.sort(true_topk, -1))


@given(probs_strategy(max_k=4))
@settings(**SETTINGS)
def test_weighted_era_uniform_equals_era(p):
    w = jnp.ones((p.shape[0],))
    np.testing.assert_allclose(agg.weighted_era(p, w, 0.1), agg.era(p, 0.1),
                               atol=1e-5)


@given(probs_strategy(max_k=4))
@settings(**SETTINGS)
def test_weighted_era_onehot_selects_client(p):
    w = jnp.zeros((p.shape[0],)).at[0].set(1.0)
    out = agg.weighted_era(p, w, 0.1)
    exp = jax.nn.softmax(p[0] / 0.1, -1)
    np.testing.assert_allclose(out, exp, atol=1e-5)


def test_era_matches_kernel_path(rng):
    p = jax.nn.softmax(jax.random.normal(rng, (6, 16, 46)), -1)
    np.testing.assert_allclose(agg.era(p, 0.1, use_kernel=True),
                               agg.era(p, 0.1), atol=1e-5)


def test_era_topk_pipeline(rng):
    p = jax.nn.softmax(jax.random.normal(rng, (4, 8, 64)) * 2, -1)
    v, i = jax.vmap(lambda x: agg.topk_compress(x, 8))(p)
    g = agg.era_topk(v, i, 64, 0.1)
    # must be a valid, sharpened distribution with argmax from the topk mean
    np.testing.assert_allclose(np.sum(np.asarray(g), -1), 1.0, atol=1e-5)


def _era_topk_dense_ref(v, i, C, T):
    """The old O(K*N*C) path: densify every client, then mean + sharpen."""
    dense = jax.vmap(lambda vv, ii: agg.topk_decompress(vv, ii, C))(v, i)
    return agg.era(dense, T)


@given(probs_strategy(max_k=5, max_n=4, max_c=8), st.integers(1, 4),
       st.sampled_from([0.1, 0.5]))
@settings(**SETTINGS)
def test_era_topk_scatter_matches_dense_path(p, k, T):
    """Satellite pin: the fused scatter-accumulate mean (no (K, N, C)
    densified intermediate) is equivalent to densify-then-mean — including
    colliding indices, where the scatter must accumulate."""
    k = min(k, p.shape[-1])
    v, i = jax.vmap(lambda x: agg.topk_compress(x, k))(p)
    np.testing.assert_allclose(agg.era_topk(v, i, p.shape[-1], T),
                               _era_topk_dense_ref(v, i, p.shape[-1], T),
                               atol=1e-5)


def test_era_topk_scatter_matches_dense_4d(rng):
    """LLM-shaped (K, n, S, k) uploads take the same fused path."""
    p = jax.nn.softmax(jax.random.normal(rng, (3, 2, 5, 32)) * 2, -1)
    v, i = jax.vmap(lambda x: agg.topk_compress(x, 4))(p)
    np.testing.assert_allclose(agg.era_topk(v, i, 32, 0.1),
                               _era_topk_dense_ref(v, i, 32, 0.1), atol=1e-6)


def test_era_topk_resparsify_roundtrip(rng):
    """k_out re-sparsifies the broadcast leg identically on both paths."""
    p = jax.nn.softmax(jax.random.normal(rng, (4, 6, 24)) * 2, -1)
    v, i = jax.vmap(lambda x: agg.topk_compress(x, 6))(p)
    gv, gi = agg.era_topk(v, i, 24, 0.1, k_out=4)
    ev, ei = agg.topk_compress(_era_topk_dense_ref(v, i, 24, 0.1), 4)
    np.testing.assert_allclose(gv, ev, atol=1e-5)
    np.testing.assert_array_equal(np.sort(np.asarray(gi), -1),
                                  np.sort(np.asarray(ei), -1))
