"""Cohort-resident federation: the million-client refactor's parity pins.

Layer by layer, the cohort path must reproduce the dense path *bitwise* at
small K: `core.prng.split_take` rows equal the dense key split's rows,
`init_cohort` slabs equal rows of the dense init stack, a lazily-filled
`ClientStore` equals the up-front store, and a full `CohortRunner` run
(O(m) slabs, id-keyed host store, per-id data provider) equals the dense
masked engine fed the same densified plans — state, touched client rows
and history floats.  Two-level ERA (`core.hierarchy`) carries the split
contract: bitwise at ``n_edges=1``, pinned tolerance with exact zero-lane
behaviour at every deeper level."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core.algorithms import DSFLAlgorithm, FDAlgorithm, FDConfig
from repro.core.cohort import ClientStore, build_slab, slab_ctx_plan
from repro.core.engine import FedEngine
from repro.core.hierarchy import (edge_shards, hierarchical_weighted_era,
                                  hierarchical_weighted_sa)
from repro.core.prng import split_take
from repro.core.protocol import DSFLConfig
from repro.data.pipeline import ArrayProvider, SyntheticProvider, \
    build_image_task
from repro.models.smallnets import apply_tiny_mlp, init_tiny_mlp
from repro.sim import (AsyncBufferScheduler, ClientPopulation, CohortRunner,
                       SimRunner, SyncScheduler)

K = 6
HP = DSFLConfig(rounds=4, local_epochs=1, distill_epochs=1, batch_size=20,
                open_batch=40, aggregation="era")


def _init(k):
    return init_tiny_mlp(k)


@pytest.fixture(scope="module")
def task():
    return build_image_task(seed=0, K=K, n_private=240, n_open=80, n_test=40,
                            distribution="non_iid")


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ------------------------------------------------------- split_take (prng) ---
@pytest.mark.parametrize("num", [1, 2, 5, 8, 33, 1000])
def test_split_take_rows_match_dense_split_bitwise(num):
    """The counter-mode pin: any row subset of ``split(key, num)`` — odd and
    even num, duplicated and unsorted ids — computed in O(m)."""
    key = jax.random.PRNGKey(7)
    dense = np.asarray(jax.random.split(key, num))
    ids = np.array([0, num - 1, num // 2, 0], np.int64) % num
    got = np.asarray(split_take(key, ids, num))
    np.testing.assert_array_equal(got, dense[ids])
    allrows = np.asarray(split_take(key, np.arange(num), num))
    np.testing.assert_array_equal(allrows, dense)


def test_split_take_hypothesis_any_ids():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(1, 600), st.data(), st.integers(0, 2**31 - 1))
    @settings(deadline=None, max_examples=25)
    def check(num, data, seed):
        ids = np.asarray(data.draw(st.lists(st.integers(0, num - 1),
                                            min_size=1, max_size=16)),
                         np.int64)
        key = jax.random.PRNGKey(seed)
        np.testing.assert_array_equal(
            np.asarray(split_take(key, ids, num)),
            np.asarray(jax.random.split(key, num))[ids])

    check()


def test_split_take_typed_key_falls_back_and_matches():
    """Non-raw keys (typed PRNG keys) take the dense-split fallback — same
    rows, just without the O(m) shortcut."""
    key = jax.random.key(3)      # typed key
    ids = np.array([4, 1, 1], np.int64)
    got = split_take(key, ids, 9)
    want = jax.random.split(key, 9)[jnp.asarray(ids)]
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(got)),
        np.asarray(jax.random.key_data(want)))


# ------------------------------------------------------ lazy init parity -----
def test_init_cohort_rows_match_dense_init_stack(task):
    """Client g's fresh state is a function of (rng, g) alone: slab rows
    equal rows of the dense `_stack_init` stack, in any order, any subset."""
    algo = DSFLAlgorithm(apply_tiny_mlp, HP)
    rng = jax.random.PRNGKey(HP.seed)
    dense = algo.init(rng, _init, task).clients
    for ids in ([2, 5], [5, 0, 3], list(range(K))):
        slab = algo.init_cohort(rng, _init, np.asarray(ids, np.int64), K)
        for la, lb in zip(jax.tree.leaves(slab), jax.tree.leaves(dense)):
            np.testing.assert_array_equal(np.asarray(la),
                                          np.asarray(lb)[np.asarray(ids)])


def test_client_store_lazy_fill_scatter_roundtrip(task):
    algo = DSFLAlgorithm(apply_tiny_mlp, HP)
    rng = jax.random.PRNGKey(HP.seed)
    store = ClientStore(lambda ids: algo.init_cohort(rng, _init, ids, K))
    assert len(store) == 0 and store.resident_bytes() == 0

    slab = store.gather(np.array([4, 1, 4]))      # duplicates allowed
    assert len(store) == 2
    dense = algo.init(rng, _init, task).clients
    for la, lb in zip(jax.tree.leaves(slab), jax.tree.leaves(dense)):
        np.testing.assert_array_equal(np.asarray(la),
                                      np.asarray(lb)[[4, 1, 4]])

    # scatter honours n_real: the pad lane (repeat of id 4) must not clobber
    mutated = jax.tree.map(lambda l: l + 1.0, slab)
    store.scatter(np.array([4, 1, 4]), mutated, n_real=2)
    back = store.gather(np.array([1, 4]))
    for la, lb in zip(jax.tree.leaves(back), jax.tree.leaves(dense)):
        np.testing.assert_array_equal(np.asarray(la),
                                      np.asarray(lb)[[1, 4]] + 1.0)
    assert store.resident_bytes() > 0


def test_client_store_save_load_roundtrip(task, tmp_path):
    algo = DSFLAlgorithm(apply_tiny_mlp, HP)
    rng = jax.random.PRNGKey(HP.seed)
    store = ClientStore(lambda ids: algo.init_cohort(rng, _init, ids, K))
    store.gather(np.array([0, 3, 5]))
    path = os.path.join(tmp_path, "clients.store")
    store.save(path)
    fresh = ClientStore(lambda ids: algo.init_cohort(rng, _init, ids, K))
    fresh.load(path)
    assert list(fresh.ids()) == [0, 3, 5]
    _assert_trees_equal(store.gather(np.array([0, 3, 5])),
                        fresh.gather(np.array([0, 3, 5])))


# ----------------------------------------------------------- slab planning ---
def test_build_slab_union_pad_and_overflow():
    ids, n_real = build_slab([np.array([4, 2]), np.array([2, 7])], 5)
    np.testing.assert_array_equal(ids, [2, 4, 7, 2, 2])
    assert n_real == 3
    with pytest.raises(ValueError, match="slab_size"):
        build_slab([np.arange(6)], 5)


def test_slab_ctx_plan_lanes_match_dense_mask():
    from repro.sim import CohortPlan
    p0 = CohortPlan(np.array([2, 7]), np.array([0, 1]), 0.0, 1.0,
                    np.zeros(0, np.int64))
    p1 = CohortPlan(np.array([4]), np.array([0]), 1.0, 2.0,
                    np.zeros(0, np.int64))
    slab_ids, n_real = build_slab([p0.ids, p1.ids], 5)
    plan = slab_ctx_plan([p0, p1], slab_ids, n_real)
    np.testing.assert_array_equal(plan["mask"],
                                  [[1, 0, 1, 0, 0], [0, 1, 0, 0, 0]])
    np.testing.assert_array_equal(plan["stale"],
                                  [[0, 0, 1, 0, 0], [0, 0, 0, 0, 0]])


# --------------------------------------------------------- two-level ERA -----
def _prob_stack(seed, k=8, n=4, c=10):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (k, n, c)) * 3
    return jax.nn.softmax(logits, -1)


def test_edge_shards_partition_properties():
    for k, n in [(8, 1), (8, 3), (7, 7), (10, 4)]:
        bounds = edge_shards(k, n)
        sizes = [e - s for s, e in bounds]
        assert bounds[0][0] == 0 and bounds[-1][1] == k
        assert all(b[0] == a[1] for a, b in zip(bounds, bounds[1:]))
        assert max(sizes) - min(sizes) <= 1
    with pytest.raises(ValueError):
        edge_shards(4, 5)
    with pytest.raises(ValueError):
        edge_shards(4, 0)


def test_hierarchy_single_edge_is_bitwise_flat():
    """The parity anchor: n_edges=1 IS the flat path, bit for bit."""
    p = _prob_stack(0)
    w = jnp.asarray([0.0, 2.0, 1.0, 0.0, 3.0, 1.0, 0.5, 0.0])
    np.testing.assert_array_equal(
        np.asarray(hierarchical_weighted_sa(p, w, n_edges=1)),
        np.asarray(agg.weighted_sa(p, w)))
    np.testing.assert_array_equal(
        np.asarray(hierarchical_weighted_era(p, w, 0.1, n_edges=1)),
        np.asarray(agg.weighted_era(p, w, 0.1)))


@pytest.mark.parametrize("n_edges", [2, 3, 4, 8])
def test_hierarchy_depth_tolerance_contract(n_edges):
    """Deeper trees re-associate the cross-client sum: equality degrades
    from bitwise to a pinned ~1e-6 tolerance — never worse."""
    p = _prob_stack(1)
    w = jnp.asarray(np.random.default_rng(1).random(8).astype(np.float32))
    flat_sa = np.asarray(agg.weighted_sa(p, w))
    flat_era = np.asarray(agg.weighted_era(p, w, 0.1))
    np.testing.assert_allclose(
        np.asarray(hierarchical_weighted_sa(p, w, n_edges=n_edges)),
        flat_sa, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(
        np.asarray(hierarchical_weighted_era(p, w, 0.1, n_edges=n_edges)),
        flat_era, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n_edges", [1, 2, 3, 8])
def test_hierarchy_zero_weight_lanes_exact_at_any_depth(n_edges):
    """What stays *exact* under re-association: a zero-weight lane
    contributes exactly nothing inside whichever edge shard it falls —
    replacing its probs with garbage cannot change a single output bit.
    This is the masking/sparse-plane guarantee surviving the hierarchy."""
    p = _prob_stack(2)
    w = jnp.asarray([0.0, 2.0, 0.0, 1.0, 3.0, 0.0, 0.5, 1.0])
    garbage = p.at[jnp.asarray([0, 2, 5])].set(123.456)
    for fn in (lambda x: hierarchical_weighted_sa(x, w, n_edges=n_edges),
               lambda x: hierarchical_weighted_era(x, w, 0.1,
                                                   n_edges=n_edges)):
        np.testing.assert_array_equal(np.asarray(fn(p)),
                                      np.asarray(fn(garbage)))


def test_hierarchy_kernel_route_matches_einsum():
    """Each edge's partial through the fused Pallas weighted-mean kernel
    (interpret mode — no accelerator needed): tolerance vs the einsum tree,
    and the n_edges=1 kernel route is exactly the flat kernel route."""
    p = _prob_stack(3)
    w = jnp.asarray(np.random.default_rng(3).random(8).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(hierarchical_weighted_sa(p, w, n_edges=1, use_kernel=True,
                                            interpret=True)),
        np.asarray(agg.weighted_sa(p, w, use_kernel=True, interpret=True)))
    np.testing.assert_allclose(
        np.asarray(hierarchical_weighted_sa(p, w, n_edges=4, use_kernel=True,
                                            interpret=True)),
        np.asarray(agg.weighted_sa(p, w)), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("agg_edges", [2, 3])
def test_dsfl_round_with_edge_tree_close_to_flat(task, agg_edges):
    """A full DSFL round aggregated through the edge tree stays within
    float tolerance of the flat round's server params after one round."""
    flat = FedEngine(DSFLAlgorithm(apply_tiny_mlp, HP))
    s1 = flat.run(flat.init(_init, task), task, rounds=1)
    algo = DSFLAlgorithm(apply_tiny_mlp, HP, agg_edges=agg_edges)
    eng = FedEngine(algo)
    s2 = eng.run(eng.init(_init, task), task, rounds=1)
    for a, b in zip(jax.tree.leaves(s1.server), jax.tree.leaves(s2.server)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# -------------------------------------------- cohort runner golden parity ----
def _dense_plan(sched_args, rounds, up, down, seed=0):
    """Replay the cohort schedule's draws and densify them into a ctx_plan
    for the dense engine — CohortPlan.dense_mask/.dense_staleness are the
    bridge (cohort draws differ from next_round's, so parity is defined
    against the *same* realized plans, not a parallel dense scheduler)."""
    sched = SyncScheduler(**sched_args)
    plans = [sched.next_cohort(np.random.default_rng([seed, i]), up, down)
             for i in range(rounds)]
    mask = jnp.asarray(np.stack([p.dense_mask(K) for p in plans]),
                       jnp.float32)
    stale = jnp.asarray(np.stack([p.dense_staleness(K) for p in plans]),
                        jnp.int32)
    return plans, {"mask": mask, "stale": stale}


@pytest.mark.parametrize("aggregation,sched_kw", [
    ("era", dict(fraction=0.5, deadline=3.0, straggler="admit")),
    ("weighted_era", dict(fraction=0.34, deadline=None, straggler="drop")),
])
def test_cohort_runner_bitwise_identical_to_dense_masked(task, aggregation,
                                                         sched_kw):
    """THE acceptance pin: a CohortRunner round — id-keyed host store, O(m)
    slab, cohort keys, slab ctx plan — is bitwise the dense masked round
    fed the same densified plans: server state, every touched client's
    stored rows, and the engine's history floats."""
    hp = dataclasses.replace(HP, aggregation=aggregation)
    algo = DSFLAlgorithm(apply_tiny_mlp, hp)
    rng0 = jax.random.PRNGKey(hp.seed)
    pop = ClientPopulation.lognormal(1, K)

    eng_c = FedEngine(algo)
    store = ClientStore(lambda ids: algo.init_cohort(rng0, _init, ids, K))
    runner = CohortRunner(engine=eng_c,
                          scheduler=SyncScheduler(pop, **sched_kw),
                          provider=ArrayProvider(task), store=store, seed=0)
    s_c = runner.run(algo.init_server(rng0, _init), rounds=4, chunk_rounds=2)

    up, down = runner._leg_bytes
    _, plan = _dense_plan(dict(population=pop, **sched_kw), 4, up, down)
    eng_d = FedEngine(algo)
    s_d = eng_d.run(eng_d.init(_init, task), task, rounds=4, chunk_rounds=2,
                    ctx_plan=plan)

    _assert_trees_equal(s_c.server, s_d.server)
    dense_clients = jax.device_get(s_d.clients)
    for cid in store.ids():
        row = store.gather(np.array([cid]))
        for la, lb in zip(jax.tree.leaves(row),
                          jax.tree.leaves(dense_clients)):
            np.testing.assert_array_equal(np.asarray(la)[0],
                                          np.asarray(lb)[int(cid)],
                                          err_msg=f"client {cid}")
    dense_hist = {r["round"]: r for r in eng_d.history}
    cohort_hist = {r["round"]: r for r in runner.history.records}
    for rnd, rec in dense_hist.items():
        for key, v in rec.items():
            if isinstance(v, float):
                assert cohort_hist[rnd][key] == v, (rnd, key)
    assert runner.peak_slab_bytes > 0


def test_cohort_runner_fd_matches_dense(task):
    """FD (no server model, empty init_server) through the cohort plane."""
    hp = FDConfig(rounds=3, local_epochs=1, batch_size=20, gamma=0.1,
                  n_classes=task.n_classes)
    algo = FDAlgorithm(apply_tiny_mlp, hp)
    rng0 = jax.random.PRNGKey(hp.seed)
    pop = ClientPopulation.lognormal(1, K)
    kw = dict(fraction=0.5, deadline=3.0, straggler="admit")

    eng_c = FedEngine(algo)
    store = ClientStore(lambda ids: algo.init_cohort(rng0, _init, ids, K))
    runner = CohortRunner(engine=eng_c, scheduler=SyncScheduler(pop, **kw),
                          provider=ArrayProvider(task), store=store, seed=0)
    runner.run(algo.init_server(rng0, _init), rounds=3, chunk_rounds=3)

    up, down = runner._leg_bytes
    _, plan = _dense_plan(dict(population=pop, **kw), 3, up, down)
    eng_d = FedEngine(algo)
    s_d = eng_d.run(eng_d.init(_init, task), task, rounds=3, chunk_rounds=3,
                    ctx_plan=plan)
    dense_clients = jax.device_get(s_d.clients)
    for cid in store.ids():
        row = store.gather(np.array([cid]))
        for la, lb in zip(jax.tree.leaves(row),
                          jax.tree.leaves(dense_clients)):
            np.testing.assert_array_equal(np.asarray(la)[0],
                                          np.asarray(lb)[int(cid)])


def test_synthetic_provider_rows_are_id_deterministic():
    """slab(ids) row j depends on ids[j] alone — any order, any cohort."""
    prov = SyntheticProvider(seed=0, n_clients=1000, n_per_client=8,
                             n_open=16, n_test=4)
    a = prov.slab(np.array([999, 3, 41]))
    b = prov.slab(np.array([3, 999]))
    np.testing.assert_array_equal(np.asarray(a.x_clients)[1],
                                  np.asarray(b.x_clients)[0])
    np.testing.assert_array_equal(np.asarray(a.x_clients)[0],
                                  np.asarray(b.x_clients)[1])
    assert a.open_x is b.open_x        # shared open set materializes once


# ------------------------------------------------- async cohort scheduler ----
def test_async_next_cohort_matches_next_round_without_jitter():
    """With zero jitter the arrival process is deterministic, so the heap
    form must realize exactly the dense argsort form's rounds — ids,
    staleness, clock — on separate instances of the same fleet."""
    def pop():
        lat = np.array([1.0, 3.5, 1.0, 2.0])
        inf = np.full_like(lat, np.inf)
        return ClientPopulation(lat, inf, inf, np.ones_like(lat))

    dense = AsyncBufferScheduler(pop(), buffer_size=2)
    heap = AsyncBufferScheduler(pop(), buffer_size=2)
    for r in range(6):
        rp = dense.next_round(np.random.default_rng(r), 0, 0)
        cp = heap.next_cohort(np.random.default_rng(r), 0, 0)
        np.testing.assert_array_equal(cp.ids, np.flatnonzero(rp.mask))
        np.testing.assert_array_equal(cp.staleness, rp.staleness[cp.ids])
        assert cp.t_end == rp.t_end
    assert dense.clock.now == heap.clock.now


def test_async_scheduler_state_roundtrip_includes_heap():
    pop = ClientPopulation.lognormal(2, 5, compute_sigma=0.8)
    sched = AsyncBufferScheduler(pop, buffer_size=2, jitter_sigma=0.2)
    for r in range(3):
        sched.next_cohort(np.random.default_rng(r), 10.0, 10.0)
    clone = AsyncBufferScheduler(pop, buffer_size=2, jitter_sigma=0.2)
    clone.set_state(sched.state())
    for r in range(3, 6):
        a = sched.next_cohort(np.random.default_rng(r), 10.0, 10.0)
        b = clone.next_cohort(np.random.default_rng(r), 10.0, 10.0)
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.staleness, b.staleness)
        assert a.t_end == b.t_end


def test_cohort_runner_async_matches_simrunner(task):
    """Async cohort rounds (heap scheduler, slab engine) against the dense
    `SimRunner` async path — same realized rounds at jitter 0, so server
    state and history must agree bitwise."""
    algo = DSFLAlgorithm(apply_tiny_mlp, HP)
    rng0 = jax.random.PRNGKey(HP.seed)

    def pop():
        lat = np.array([1.0, 3.5, 1.0, 2.0, 1.5, 2.5])
        inf = np.full_like(lat, np.inf)
        return ClientPopulation(lat, inf, inf, np.ones_like(lat))

    eng_c = FedEngine(algo)
    store = ClientStore(lambda ids: algo.init_cohort(rng0, _init, ids, K))
    runner = CohortRunner(engine=eng_c,
                          scheduler=AsyncBufferScheduler(pop(),
                                                         buffer_size=2),
                          provider=ArrayProvider(task), store=store, seed=0)
    s_c = runner.run(algo.init_server(rng0, _init), rounds=3)

    eng_d = FedEngine(algo)
    sim = SimRunner(eng_d, AsyncBufferScheduler(pop(), buffer_size=2),
                    seed=0)
    s_d = sim.run(eng_d.init(_init, task), task, rounds=3)
    _assert_trees_equal(s_c.server, s_d.server)
    dense_clients = jax.device_get(s_d.clients)
    for cid in store.ids():
        row = store.gather(np.array([cid]))
        for la, lb in zip(jax.tree.leaves(row),
                          jax.tree.leaves(dense_clients)):
            np.testing.assert_array_equal(np.asarray(la)[0],
                                          np.asarray(lb)[int(cid)])
