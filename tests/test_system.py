"""End-to-end behaviour: the paper's central claims at micro scale, plus the
serving path.  (Full-scale claim validation lives in benchmarks/.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.protocol import DSFLConfig, DSFLEngine, make_eval_fn
from repro.data.pipeline import build_image_task
from repro.models.smallnets import apply_mnist_cnn, init_mnist_cnn

K = 4


def _init(k):
    return init_mnist_cnn(k, image_hw=16, widths=(8, 16), fc=32)


def _run(hp, task, rng, corrupt=None):
    wg, sg = _init(rng)
    wk = jax.vmap(lambda k: _init(k)[0])(jax.random.split(rng, K))
    sk = jax.vmap(lambda k: _init(k)[1])(jax.random.split(rng, K))
    eng = DSFLEngine(apply_mnist_cnn, hp,
                     make_eval_fn(apply_mnist_cnn, task.x_test, task.y_test),
                     corrupt=corrupt)
    eng.run(wk, sk, wg, sg, task.x_clients, task.y_clients, task.open_x)
    return eng.history


@pytest.fixture(scope="module")
def task():
    return build_image_task(seed=1, K=K, n_private=640, n_open=320,
                            n_test=320, distribution="non_iid")


def test_era_converges_at_least_as_fast_as_sa(task, rng):
    """Paper claim: ERA accelerates convergence under non-IID (Fig. 5/6)."""
    hp_era = DSFLConfig(rounds=4, local_epochs=2, distill_epochs=2,
                        batch_size=40, open_batch=160, aggregation="era")
    hp_sa = DSFLConfig(rounds=4, local_epochs=2, distill_epochs=2,
                       batch_size=40, open_batch=160, aggregation="sa")
    h_era = _run(hp_era, task, rng)
    h_sa = _run(hp_sa, task, rng)
    # cumulative accuracy (area under the curve) as a convergence-speed proxy
    auc_era = sum(h["test_acc"] for h in h_era)
    auc_sa = sum(h["test_acc"] for h in h_sa)
    assert auc_era >= auc_sa * 0.9      # ERA >= SA (within noise at 4 rounds)
    assert h_era[-1]["global_entropy"] < h_sa[-1]["global_entropy"]


def test_serve_greedy_is_deterministic(rng):
    from repro.launch.serve import serve
    from repro.configs import get_config
    from repro.models.api import model_init
    cfg = get_config("qwen1.5-4b").smoke()
    params = model_init(cfg, rng)
    batch = {"tokens": jax.random.randint(rng, (2, 8), 0, cfg.vocab,
                                          jnp.int32)}
    t1, _ = serve(cfg, params, batch, gen=4, seq_budget=16)
    t2, _ = serve(cfg, params, batch, gen=4, seq_budget=16)
    np.testing.assert_array_equal(t1, t2)


def test_quickstart_example_runs():
    import subprocess, sys, os
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "examples/quickstart.py", "--fast"],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
