"""Property-based tests (hypothesis) for the wire codecs: the TopKCodec
encode/decode roundtrip invariants over random shapes, k, and inputs —
the wire-parity counterpart of the LLM comm tests in
tests/test_llm_algorithms.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import wire
from repro.core.comm import FLOAT_BYTES, INT_BYTES

SETTINGS = dict(deadline=None, max_examples=30,
                suppress_health_check=[HealthCheck.too_slow])


@st.composite
def probs_and_k(draw, max_n=6, max_c=12):
    N = draw(st.integers(1, max_n))
    C = draw(st.integers(2, max_c))
    k = draw(st.integers(1, C))
    seed = draw(st.integers(0, 2**31 - 1))
    logits = jax.random.normal(jax.random.PRNGKey(seed), (N, C)) * 3
    return jax.nn.softmax(logits, -1), k


@given(probs_and_k())
@settings(**SETTINGS)
def test_topk_codec_roundtrip_invariants(pk):
    p, k = pk
    C = p.shape[-1]
    codec = wire.TopKCodec(k=k, n_classes=C)
    enc = codec.encode(p)
    out = np.asarray(codec.decode(enc))
    # decoded payload is a renormalized distribution...
    np.testing.assert_allclose(out.sum(-1), 1.0, atol=1e-5)
    assert np.all(out >= 0)
    # ...supported on the true top-k of the input
    assert np.all((out > 0).sum(-1) <= k)
    true_topk = np.argsort(-np.asarray(p), axis=-1)[..., :k]
    kept = np.sort(np.asarray(enc["i"]), -1)
    assert np.all(kept == np.sort(true_topk, -1))
    # ...and exact when k == C
    if k == C:
        np.testing.assert_allclose(out, np.asarray(p), atol=1e-5)


@given(probs_and_k())
@settings(**SETTINGS)
def test_topk_codec_payload_bytes_are_k_pairs(pk):
    p, k = pk
    N, C = p.shape
    codec = wire.TopKCodec(k=k, n_classes=C)
    enc = codec.encode(p)
    assert jax.tree.leaves(enc["v"])[0].dtype == jnp.float32
    assert jax.tree.leaves(enc["i"])[0].dtype == jnp.int32
    assert codec.payload_bytes(enc) == N * k * (FLOAT_BYTES + INT_BYTES)


# ------------------------------------------------------------- Int8Codec ----
@st.composite
def tensors(draw, max_n=6, max_c=12):
    N = draw(st.integers(1, max_n))
    C = draw(st.integers(1, max_c))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.floats(1e-3, 1e3))
    return jax.random.normal(jax.random.PRNGKey(seed), (N, C)) * scale


@given(tensors())
@settings(**SETTINGS)
def test_int8_codec_roundtrip_error_bound(x):
    """Per-tensor affine quantization: the decode error is bounded by half a
    quantization step, scale = (max - min) / 255."""
    codec = wire.Int8Codec()
    enc = codec.encode(x)
    out = np.asarray(codec.decode(enc))
    scale = float(enc["scale"])
    bound = scale / 2 * (1 + 1e-3) + 1e-7
    assert np.max(np.abs(out - np.asarray(x))) <= bound
    # the quantized leaf really is one byte per element
    assert enc["q"].dtype == jnp.uint8
    assert codec.payload_bytes(enc) == x.size * 1 + 8


@given(tensors())
@settings(**SETTINGS)
def test_int8_codec_constant_tensor_is_exact(x):
    """Degenerate range (max == min) must not divide by zero and decodes
    back to the constant."""
    codec = wire.Int8Codec()
    const = jnp.full_like(x, float(x[0, 0]))
    out = np.asarray(codec.decode(codec.encode(const)))
    np.testing.assert_allclose(out, np.asarray(const), rtol=1e-6, atol=1e-9)


@given(probs_and_k(), st.integers(1, 3))
@settings(**SETTINGS)
def test_topk_codec_roundtrip_on_pytrees(pk, depth):
    """Codecs must map over whole payload pytrees (the upload is a pytree)."""
    p, k = pk
    codec = wire.TopKCodec(k=k, n_classes=p.shape[-1])
    tree = {"a": p}
    for _ in range(depth):
        tree = {"nest": tree}
    out = codec.decode(codec.encode(tree))
    leaf = jax.tree.leaves(out)[0]
    np.testing.assert_allclose(np.asarray(leaf).sum(-1), 1.0, atol=1e-5)
