"""repro.serve: continuous batching + live weight hot-swap.

The load-bearing pins:
  * slot-engine parity — N staggered requests through one shared engine are
    token-identical to serving each alone, and (attention archs, bucket-exact
    prompts) to the pre-subsystem lockstep baseline in `launch.serve`;
  * fused-decode parity — ``step(decode_chunk=d)`` is token-identical to d
    single steps on both archs, including mid-chunk finishers (max-token and
    EOS), with identical virtual timestamps and accounted step counts;
  * batched-prefill parity — ``insert_batch`` (including a padded
    batch-size class) is token-identical to inserting each request alone;
  * no recompiles after warmup — the decode step compiles exactly once, each
    chunk size exactly once, each prefill bucket exactly once (short prompts
    share the bucket-1 program: the prefill compile set IS the bucket set),
    and each (bucket, batch-class) exactly once, no matter how many requests
    are admitted/evicted (asserted through the jit cache size);
  * hot-swap — a live `FedEngine` run swaps the server's weights at chunk
    boundaries: responses before/after carry the old/new version stamps, the
    swap adds zero compiles, and a mid-request swap at a fused-chunk
    boundary is token-identical to the same swap between single steps;
  * queue invariants (hypothesis) — every submitted request is accounted
    exactly once, admission (grouped or not) never exceeds the free-slot
    budget, FIFO holds within each bucket, and every grouped-admit batch is
    single-bucket.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import FedEngine
from repro.core.llm_algorithms import LLMDSFLAlgorithm
from repro.core.llm_dsfl import LLMDsflHP
from repro.data.pipeline import build_lm_task
from repro.launch.serve import serve as lockstep_serve
from repro.launch.serve import steady_ms_per_step
from repro.models.api import model_init
from repro.serve import (AdmissionQueue, LoadSpec, Request, ServeEngine,
                         attach, bucket_of, draw_arrivals, run_load,
                         swap_from_checkpoint)

QWEN = get_config("qwen1.5-4b").smoke()
MAMBA = get_config("mamba2-2.7b").smoke()
BUCKETS = (8, 16)
BUDGET = 48


@pytest.fixture(scope="module")
def qwen_params(rng):
    return model_init(QWEN, rng)


@pytest.fixture(scope="module")
def mamba_params(rng):
    return model_init(MAMBA, rng)


def _prompts(vocab, lens, seed=3):
    g = np.random.default_rng(seed)
    return [tuple(int(x) for x in g.integers(0, vocab, size=S)) for S in lens]


def _drain(engine, now=0.0):
    out = []
    while engine.n_active:
        now += 1.0
        engine.step(now)
        out.extend(engine.pop_completed())
    return out


def _solo(cfg, params, tokens, max_new):
    eng = ServeEngine(cfg, params, slots=1, seq_budget=BUDGET,
                      buckets=BUCKETS)
    eng.insert(Request(id=0, tokens=tokens, max_new_tokens=max_new))
    (r,) = _drain(eng)
    return r.tokens


# ------------------------------------------------------------------ parity --
@pytest.mark.parametrize("arch", ["qwen", "mamba"])
def test_staggered_requests_match_each_alone(arch, qwen_params, mamba_params):
    """Continuous batching must not change tokens: requests of different
    prompt lengths admitted at different times, sharing the slot batch with
    whoever else is mid-flight, decode exactly as if each ran alone."""
    cfg, params = ((QWEN, qwen_params) if arch == "qwen"
                   else (MAMBA, mamba_params))
    prompts = _prompts(cfg.vocab, lens=(5, 12, 20, 16))
    max_new = 6
    solo = [_solo(cfg, params, p, max_new) for p in prompts]

    eng = ServeEngine(cfg, params, slots=3, seq_budget=BUDGET,
                      buckets=BUCKETS)
    q = AdmissionQueue(buckets=BUCKETS)
    for i, p in enumerate(prompts):            # staggered arrivals
        q.submit(p, max_new, now=float(i))
    got, now = {}, 0.0
    while len(got) < len(prompts):
        for req in q.admit(now, len(eng.free_slots())):
            eng.insert(req, now)
        for r in eng.step(now):
            got[r.id] = r.tokens
        now += 1.0
    assert [got[i] for i in range(len(prompts))] == solo


def test_engine_matches_lockstep_baseline(qwen_params):
    """With bucket-exact prompts on an attention arch the slot engine is
    token-identical to the pre-subsystem whole-batch lockstep path."""
    B, S, gen = 3, 16, 8
    g = np.random.default_rng(0)
    tokens = g.integers(0, QWEN.vocab, size=(B, S))
    budget = S + gen
    base, times = lockstep_serve(QWEN, qwen_params,
                                 {"tokens": jnp.asarray(tokens, jnp.int32)},
                                 gen, budget)
    assert steady_ms_per_step(times) > 0.0
    base = np.asarray(base)

    eng = ServeEngine(QWEN, qwen_params, slots=B, seq_budget=budget,
                      buckets=(S,))
    for i in range(B):
        eng.insert(Request(id=i, tokens=tuple(int(t) for t in tokens[i]),
                           max_new_tokens=gen))
    got = {r.id: r.tokens for r in _drain(eng)}
    for i in range(B):
        assert got[i] == tuple(int(t) for t in base[i])


# ------------------------------------------------------------ fused decode --
def _drive_chunked(cfg, params, prompts, max_news, d, eos_id=None, dt=0.5):
    """All requests resident from t=0 (slots == #requests), decoded with
    ``decode_chunk=d`` under the loadgen's virtual-clock discipline: sub-step
    j of a chunk happens at the same virtual time the d=1 loop's j-th step
    would."""
    eng = ServeEngine(cfg, params, slots=len(prompts), seq_budget=BUDGET,
                      buckets=BUCKETS, eos_id=eos_id)
    for i, (p, m) in enumerate(zip(prompts, max_news)):
        eng.insert(Request(id=i, tokens=p, max_new_tokens=m), now=0.0)
    out, now = list(eng.pop_completed()), 0.0   # EOS can finish at insert
    while eng.n_active:
        before = eng.n_steps
        now += dt
        out.extend(eng.step(now, decode_chunk=d, step_dt=dt))
        now += (eng.n_steps - before - 1) * dt
    return {r.id: r for r in out}, eng


@pytest.mark.parametrize("arch", ["qwen", "mamba"])
def test_fused_decode_chunk_matches_single_step(arch, qwen_params,
                                                mamba_params):
    """decode_chunk=d is pure schedule: tokens, first-token/finish
    timestamps, and accounted step counts are all identical to d single
    steps — while the device round-trips collapse by ~d.  The workload is
    chosen so requests finish mid-chunk (max_new 2/6/9 against d=4 and
    d=16) and prompt tails cross chunk boundaries."""
    cfg, params = ((QWEN, qwen_params) if arch == "qwen"
                   else (MAMBA, mamba_params))
    prompts = _prompts(cfg.vocab, lens=(3, 12, 20), seed=5)
    max_news = (2, 6, 9)
    base, beng = _drive_chunked(cfg, params, prompts, max_news, d=1)
    for d in (4, 16):
        got, eng = _drive_chunked(cfg, params, prompts, max_news, d=d)
        assert eng.n_steps == beng.n_steps          # accounted sub-steps
        assert eng.n_dispatches < beng.n_dispatches  # but far fewer syncs
        for i in base:
            assert got[i].tokens == base[i].tokens
            assert got[i].first_token_at == base[i].first_token_at
            assert got[i].finished_at == base[i].finished_at


def test_fused_decode_eos_finish_mid_chunk(qwen_params):
    """A lane hitting EOS inside a fused chunk freezes exactly where the
    per-step loop would have evicted it.  Request 0 carries a prompt tail,
    so its first emission — chosen as the EOS — lands at sub-step 3 of the
    chunk; request 1 keeps the chunk decoding past that finish, exercising
    the frozen-lane masking."""
    prompts = _prompts(QWEN.vocab, lens=(12, 8), seed=5)
    max_news = (8, 8)
    free_run, _ = _drive_chunked(QWEN, qwen_params, prompts, max_news, d=1)
    eos = free_run[0].tokens[0]              # req 0 finishes on first emit
    base, _ = _drive_chunked(QWEN, qwen_params, prompts, max_news, d=1,
                             eos_id=eos)
    assert len(base[0].tokens) < 8           # EOS cut generation short
    for d in (4, 16):
        got, eng = _drive_chunked(QWEN, qwen_params, prompts, max_news, d=d,
                                  eos_id=eos)
        for i in base:
            assert got[i].tokens == base[i].tokens
            assert got[i].finished_at == base[i].finished_at


# ------------------------------------------------------------- no recompile --
def test_no_recompile_after_warmup(qwen_params):
    """Admission, eviction, and slot churn never trigger a recompile: after
    the first request of each bucket length, jit cache sizes are pinned."""
    eng = ServeEngine(QWEN, qwen_params, slots=2, seq_budget=BUDGET,
                      buckets=BUCKETS)
    warm = _prompts(QWEN.vocab, lens=(10, 17), seed=1)
    for i, p in enumerate(warm):
        eng.insert(Request(id=i, tokens=p, max_new_tokens=3))
    _drain(eng)
    pinned = eng.compile_counts()
    assert pinned["step"] == 1
    assert set(pinned["prefill"]) == {8, 16}

    # churn: 6 more requests across both buckets, arriving mid-flight
    for j, p in enumerate(_prompts(QWEN.vocab, lens=(9, 21, 8, 16, 30, 11),
                                   seed=2)):
        while not eng.free_slots():
            eng.step()
        eng.insert(Request(id=10 + j, tokens=p, max_new_tokens=2))
        eng.step()
    _drain(eng)
    assert eng.compile_counts() == pinned


def test_decode_chunk_toggle_never_recompiles(qwen_params):
    """Each chunk size keys its own jit entry: after one request per size,
    interleaving d in {1, 4, 8} across further requests adds nothing."""
    eng = ServeEngine(QWEN, qwen_params, slots=2, seq_budget=BUDGET,
                      buckets=BUCKETS)
    prompts = iter(_prompts(QWEN.vocab, lens=(12,) * 12, seed=9))
    ids = iter(range(100))

    def serve_once(d):
        while not eng.free_slots():
            eng.step(decode_chunk=d)
        eng.insert(Request(id=next(ids), tokens=next(prompts),
                           max_new_tokens=6))
        while eng.n_active:
            eng.step(decode_chunk=d)
        eng.pop_completed()

    for d in (1, 4, 8):
        serve_once(d)
    pinned = eng.compile_counts()
    assert pinned["step"] == 1
    assert pinned["decode_chunk"] == {4: 1, 8: 1}
    for d in (8, 1, 4, 8, 4, 1):
        serve_once(d)
    assert eng.compile_counts() == pinned


def test_short_prompts_share_the_length1_prefill(qwen_params):
    """The bucket-leak regression: prompts shorter than every configured
    bucket prefill through the always-present length-1 program — one
    compile total, not one per distinct short length — and decode
    token-identically to an engine with an exact-length bucket."""
    eng = ServeEngine(QWEN, qwen_params, slots=2, seq_budget=BUDGET,
                      buckets=BUCKETS)
    assert eng.buckets == (1, 8, 16)
    for i, p in enumerate(_prompts(QWEN.vocab, lens=(3, 5, 7), seed=4)):
        while not eng.free_slots():
            eng.step()
        eng.insert(Request(id=i, tokens=p, max_new_tokens=4))
    got = {r.id: r.tokens for r in _drain(eng)}
    counts = eng.compile_counts()
    assert set(counts["prefill"]) == {1}            # not {3, 5, 7}
    assert set(counts["prefill"]) <= set(eng.buckets)

    # fallback parity: length-1 prefix + forced tail == exact-length prefill
    p5 = _prompts(QWEN.vocab, lens=(3, 5, 7), seed=4)[1]
    exact = ServeEngine(QWEN, qwen_params, slots=1, seq_budget=BUDGET,
                        buckets=(5,))
    exact.insert(Request(id=0, tokens=p5, max_new_tokens=4))
    (r,) = _drain(exact)
    assert r.tokens == got[1]


# ------------------------------------------------------------ batched insert --
@pytest.mark.parametrize("arch", ["qwen", "mamba"])
def test_insert_batch_matches_single_insert(arch, qwen_params, mamba_params):
    """One compiled shot for a same-bucket group — padded up to the
    power-of-two batch class — is token-identical to inserting each request
    alone, and the (bucket, class) program is shared across groups."""
    cfg, params = ((QWEN, qwen_params) if arch == "qwen"
                   else (MAMBA, mamba_params))
    prompts = _prompts(cfg.vocab, lens=(9, 12, 15), seed=6)
    max_new = 5
    solo = [_solo(cfg, params, p, max_new) for p in prompts]

    eng = ServeEngine(cfg, params, slots=4, seq_budget=BUDGET,
                      buckets=BUCKETS)
    claimed = eng.insert_batch(
        [Request(id=i, tokens=p, max_new_tokens=max_new)
         for i, p in enumerate(prompts)])
    assert claimed == [0, 1, 2] and eng.n_prefill_shots == 1
    got = {r.id: r.tokens for r in _drain(eng)}
    assert [got[i] for i in range(3)] == solo
    # m=3 rode the padded class-4 program: one compile per (bucket, class)
    assert eng.compile_counts()["prefill_batch"] == {"8x4": 1}

    # a full-width group reuses the exact same program
    eng.insert_batch(
        [Request(id=10 + i, tokens=p, max_new_tokens=2)
         for i, p in enumerate(_prompts(cfg.vocab,
                                        lens=(8, 9, 10, 11), seed=7))])
    _drain(eng)
    assert eng.compile_counts()["prefill_batch"] == {"8x4": 1}


def test_insert_batch_validation(qwen_params):
    eng = ServeEngine(QWEN, qwen_params, slots=2, seq_budget=BUDGET,
                      buckets=BUCKETS)
    mixed = [Request(id=0, tokens=tuple(range(1, 6)), max_new_tokens=2),
             Request(id=1, tokens=tuple(range(1, 13)), max_new_tokens=2)]
    with pytest.raises(ValueError, match="same-bucket"):
        eng.insert_batch(mixed)
    many = [Request(id=i, tokens=tuple(range(1, 10)), max_new_tokens=2)
            for i in range(3)]
    with pytest.raises(RuntimeError, match="free slots"):
        eng.insert_batch(many)
    assert eng.insert_batch([]) == []


def test_insert_rejects_over_budget(qwen_params):
    eng = ServeEngine(QWEN, qwen_params, slots=1, seq_budget=16,
                      buckets=(8,))
    with pytest.raises(ValueError, match="seq_budget"):
        eng.insert(Request(id=0, tokens=tuple(range(12)), max_new_tokens=8))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.insert(Request(id=1, tokens=(), max_new_tokens=2))


# ------------------------------------------------------------------ loadgen --
def test_loadgen_deterministic_and_accounted(qwen_params):
    spec = LoadSpec(n_requests=12, rate=6.0, prompt_len=(3, 30),
                    max_new=(2, 6), vocab=QWEN.vocab, seed=11)
    assert draw_arrivals(spec) == draw_arrivals(spec)   # seeded: identical

    eng = ServeEngine(QWEN, qwen_params, slots=3, seq_budget=BUDGET,
                      buckets=BUCKETS)
    q = AdmissionQueue(buckets=BUCKETS, timeout=60.0, max_queue=32)
    rep = run_load(eng, q, spec)
    assert rep["completed"] + rep["shed"] == spec.n_requests
    assert rep["tokens"] > 0 and rep["latency_p50_s"] > 0
    assert rep["latency_p99_s"] >= rep["latency_p50_s"]
    assert rep["compiles"]["step"] == 1


# ----------------------------------------------------------------- hot swap --
def test_hot_swap_from_live_fed_engine(rng):
    """Train-while-serving: a FedEngine LLM DSFL run hot-swaps the server's
    weights at every chunk boundary.  Responses decoded before the run carry
    version 0, responses after carry the final round number, and the swap
    adds zero compiled programs."""
    K, B, S = 2, 4, 32
    task = build_lm_task(seed=0, K=K, batch=B, seq=S, vocab=QWEN.vocab)
    hp = LLMDsflHP(lr=5e-3, rounds=2, seed=0, open_batch=B)
    algo = LLMDSFLAlgorithm(QWEN, hp)
    stacked = jax.vmap(lambda k: model_init(QWEN, k))(jax.random.split(rng, K))
    fed = FedEngine(algo)
    state = algo.init_from(stacked)

    srv = ServeEngine(QWEN, model_init(QWEN, rng), slots=2,
                      seq_budget=BUDGET, buckets=BUCKETS)
    prompt = _prompts(QWEN.vocab, lens=(12,))[0]

    srv.insert(Request(id=0, tokens=prompt, max_new_tokens=4))
    (before,) = _drain(srv)
    assert before.weights_version == 0
    pinned = srv.compile_counts()

    sync = attach(fed, srv, algo)
    state = fed.run(state, task, rounds=2)
    assert [r for r, _ in sync.swap_log] == [1, 2]
    assert all(dt >= 0 for _, dt in sync.swap_log)
    assert srv.version == 2

    srv.insert(Request(id=1, tokens=prompt, max_new_tokens=4))
    (after,) = _drain(srv)
    assert after.weights_version == 2
    assert srv.compile_counts() == pinned       # swap never recompiles

    # the served weights ARE the trained global model
    want, _ = algo.eval_params(state)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), want, srv.params)


@pytest.mark.parametrize("arch", ["qwen", "mamba"])
def test_hot_swap_lands_at_chunk_boundary(arch, qwen_params, mamba_params):
    """`step` syncs its fused chunk before returning, so a swap can never
    interleave with an in-flight chunk: a mid-request swap between chunks
    is token-identical to the same swap between single steps at the same
    token index, stamps the same version, and adds zero compiles."""
    cfg, params = ((QWEN, qwen_params) if arch == "qwen"
                   else (MAMBA, mamba_params))
    new = model_init(cfg, jax.random.PRNGKey(9))
    prompt = _prompts(cfg.vocab, lens=(8,), seed=8)[0]

    def run(d, swap):
        eng = ServeEngine(cfg, params, slots=1, seq_budget=BUDGET,
                          buckets=BUCKETS)
        eng.insert(Request(id=0, tokens=prompt, max_new_tokens=9))
        while eng.n_steps < 4:                  # 4 decode steps, any chunking
            eng.step(decode_chunk=d)
        pinned = eng.compile_counts()
        if swap:
            eng.swap_weights(new, version=5)
        while eng.n_active:
            eng.step(decode_chunk=d)
        (r,) = eng.pop_completed()
        assert eng.compile_counts() == pinned   # swap adds zero compiles
        return r, eng

    single, _ = run(1, swap=True)
    chunked, eng = run(4, swap=True)
    assert chunked.tokens == single.tokens
    assert chunked.weights_version == single.weights_version == 5
    # the remaining chunks really decoded under the swapped-in weights
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), new, eng.params)


def test_swap_mismatch_names_leaves(qwen_params):
    srv = ServeEngine(QWEN, qwen_params, slots=1, seq_budget=16,
                      buckets=(8,))
    bad = jax.tree.map(lambda a: a, qwen_params)
    key = sorted(bad)[0]
    bad[key] = jax.tree.map(lambda a: a[..., :1], bad[key])
    with pytest.raises(ValueError, match=key):
        srv.swap_weights(bad)


def test_swap_from_checkpoint(tmp_path, qwen_params):
    from repro.checkpoint import save_pytree
    srv = ServeEngine(QWEN, qwen_params, slots=1, seq_budget=16,
                      buckets=(8,))
    new = jax.tree.map(lambda a: a * 0.5, qwen_params)
    path = str(tmp_path / "weights.msgpack")
    save_pytree(path, new)
    dt = swap_from_checkpoint(srv, path, version=7)
    assert dt >= 0 and srv.version == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), new, srv.params)


def test_load_state_mismatch_names_leaves(rng, tmp_path):
    """A checkpoint saved from a different config fails loudly at load time
    with the offending leaves named, not later inside a jit."""
    K, B, S = 2, 4, 32
    hp = LLMDsflHP(lr=5e-3, rounds=1, seed=0, open_batch=B)
    algo = LLMDSFLAlgorithm(QWEN, hp)
    stacked = jax.vmap(lambda k: model_init(QWEN, k))(jax.random.split(rng, K))
    fed = FedEngine(algo)
    state = algo.init_from(stacked)
    path = str(tmp_path / "state.msgpack")
    fed.save_state(path, state)

    wrong = jax.tree.map(lambda a: a, state)
    with pytest.raises(ValueError, match="does not match"):
        like = jax.tree.map(
            lambda a: a[..., :1] if a.ndim > 1 else a, wrong)
        fed.load_state(path, like)


# ---------------------------------------------------------- queue invariants --
def test_bucket_of():
    assert bucket_of(20, (8, 16, 32)) == 16
    assert bucket_of(16, (8, 16, 32)) == 16
    assert bucket_of(5, (8, 16, 32)) == 5     # shorter than every bucket
    assert bucket_of(100, (8, 16, 32)) == 32


def test_queue_invariants_property():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    ops = st.lists(st.tuples(st.booleans(),           # submit vs admit
                             st.integers(1, 40),      # prompt len / slots
                             st.integers(0, 3)),      # clock increment
                   max_size=60)

    @settings(deadline=None, max_examples=80)
    @given(ops)
    def run(events):
        q = AdmissionQueue(buckets=(8, 16), timeout=4.0, max_queue=5)
        now, admitted = 0.0, []
        for is_submit, a, dt in events:
            now += dt * 0.75
            if is_submit:
                q.submit(tuple(range(a)), 4, now=now)
            else:
                free = a % 4
                got = q.admit(now, free)
                assert len(got) <= free          # never exceeds slot budget
                admitted.extend(got)
        q.shed_expired(now + 1e9)                # flush whatever remains
        assert len(q) == 0
        # exactly-once accounting: submitted == admitted + shed, no dupes
        ids = [r.id for r in admitted] + [r.id for r in q.shed]
        assert len(ids) == len(set(ids)) == q.n_submitted
        assert q.n_admitted == len(admitted)
        for r in q.shed:
            assert r.shed and r.tokens == ()
        # FIFO within each bucket: ids are issued in submit order
        per_bucket = {}
        for r in admitted:
            per_bucket.setdefault(bucket_of(r.prompt_len, (8, 16)),
                                  []).append(r.id)
        for got_ids in per_bucket.values():
            assert got_ids == sorted(got_ids)

    run()


def test_grouped_admit_property():
    """admit(group=True) — the batched-prefill grouping mode: every batch
    is single-bucket and led by the globally oldest queued request, never
    exceeds the free-slot budget, preserves FIFO within each bucket, and
    accounts every submitted request exactly once."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    BK = (8, 16)
    ops = st.lists(st.tuples(st.booleans(),           # submit vs admit
                             st.integers(1, 40),      # prompt len / free
                             st.integers(0, 4)),
                   max_size=60)

    @settings(deadline=None, max_examples=80)
    @given(ops)
    def run(events):
        q = AdmissionQueue(buckets=BK)                # unbounded: no shed
        pending, admitted, now = [], [], 0.0
        for is_submit, a, _ in events:
            now += 0.5                                # arrivals strictly order
            if is_submit:
                pending.append(q.submit(tuple(range(a)), 4, now=now))
            else:
                free = a % 5
                got = q.admit(now, free, group=True)
                assert len(got) <= free               # slot budget holds
                if got:
                    buckets = {bucket_of(r.prompt_len, BK) for r in got}
                    assert len(buckets) == 1          # one bucket per shot
                    # the group is led by the globally oldest request
                    oldest = min(pending, key=lambda r: r.arrival)
                    assert got[0].id == oldest.id
                    for r in got:
                        pending.remove(r)
                    admitted.extend(got)
        while True:                                   # grouped admits drain
            got = q.admit(now, 3, group=True)
            if not got:
                break
            assert len({bucket_of(r.prompt_len, BK) for r in got}) == 1
            admitted.extend(got)
        assert len(q) == 0
        ids = [r.id for r in admitted]                # exactly-once
        assert len(ids) == len(set(ids)) == q.n_submitted == q.n_admitted
        per_bucket = {}
        for r in admitted:
            per_bucket.setdefault(bucket_of(r.prompt_len, BK),
                                  []).append(r.id)
        for got_ids in per_bucket.values():           # FIFO within bucket
            assert got_ids == sorted(got_ids)

    run()


def test_no_shed_percentiles_are_json_null(qwen_params):
    """An empty percentile series (here: the shed-wait stats of a run that
    shed nothing) reports None — JSON null — not a -1.0 sentinel that a
    reader could mistake for a measured latency."""
    spec = LoadSpec(n_requests=6, rate=4.0, prompt_len=(3, 20),
                    max_new=(2, 4), vocab=QWEN.vocab, seed=7)
    eng = ServeEngine(QWEN, qwen_params, slots=2, seq_budget=BUDGET,
                      buckets=BUCKETS)
    rep = run_load(eng, AdmissionQueue(buckets=eng.buckets), spec)
    rep.pop("responses")
    assert rep["shed"] == 0 and rep["completed"] == 6
    for k in ("shed_wait_p50_s", "shed_wait_p90_s", "shed_wait_p99_s"):
        assert rep[k] is None
    json.dumps(rep)                                   # serializable as null


def test_queue_timeout_and_overload_shed():
    q = AdmissionQueue(buckets=(8,), timeout=1.0, max_queue=2)
    q.submit((1, 2, 3), 4, now=0.0)
    q.submit((1, 2, 3), 4, now=0.1)
    q.submit((1, 2, 3), 4, now=0.2)              # over max_queue: shed now
    assert len(q.shed) == 1 and q.shed[0].shed
    assert q.admit(now=5.0, free_slots=4) == []  # both expired meanwhile
    assert len(q.shed) == 3
    assert q.n_submitted == 3 and len(q) == 0
