"""repro.serve: continuous batching + live weight hot-swap.

The load-bearing pins:
  * slot-engine parity — N staggered requests through one shared engine are
    token-identical to serving each alone, and (attention archs, bucket-exact
    prompts) to the pre-subsystem lockstep baseline in `launch.serve`;
  * no recompiles after warmup — the decode step compiles exactly once and
    each prefill bucket exactly once, no matter how many requests are
    admitted/evicted (asserted through the jit cache size);
  * hot-swap — a live `FedEngine` run swaps the server's weights at chunk
    boundaries: responses before/after carry the old/new version stamps and
    the swap adds zero compiles;
  * queue invariants (hypothesis) — every submitted request is accounted
    exactly once, admission never exceeds the free-slot budget, FIFO holds
    within each bucket.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import FedEngine
from repro.core.llm_algorithms import LLMDSFLAlgorithm
from repro.core.llm_dsfl import LLMDsflHP
from repro.data.pipeline import build_lm_task
from repro.launch.serve import serve as lockstep_serve
from repro.launch.serve import steady_ms_per_step
from repro.models.api import model_init
from repro.serve import (AdmissionQueue, LoadSpec, Request, ServeEngine,
                         attach, bucket_of, draw_arrivals, run_load,
                         swap_from_checkpoint)

QWEN = get_config("qwen1.5-4b").smoke()
MAMBA = get_config("mamba2-2.7b").smoke()
BUCKETS = (8, 16)
BUDGET = 48


@pytest.fixture(scope="module")
def qwen_params(rng):
    return model_init(QWEN, rng)


@pytest.fixture(scope="module")
def mamba_params(rng):
    return model_init(MAMBA, rng)


def _prompts(vocab, lens, seed=3):
    g = np.random.default_rng(seed)
    return [tuple(int(x) for x in g.integers(0, vocab, size=S)) for S in lens]


def _drain(engine, now=0.0):
    out = []
    while engine.n_active:
        now += 1.0
        engine.step(now)
        out.extend(engine.pop_completed())
    return out


def _solo(cfg, params, tokens, max_new):
    eng = ServeEngine(cfg, params, slots=1, seq_budget=BUDGET,
                      buckets=BUCKETS)
    eng.insert(Request(id=0, tokens=tokens, max_new_tokens=max_new))
    (r,) = _drain(eng)
    return r.tokens


# ------------------------------------------------------------------ parity --
@pytest.mark.parametrize("arch", ["qwen", "mamba"])
def test_staggered_requests_match_each_alone(arch, qwen_params, mamba_params):
    """Continuous batching must not change tokens: requests of different
    prompt lengths admitted at different times, sharing the slot batch with
    whoever else is mid-flight, decode exactly as if each ran alone."""
    cfg, params = ((QWEN, qwen_params) if arch == "qwen"
                   else (MAMBA, mamba_params))
    prompts = _prompts(cfg.vocab, lens=(5, 12, 20, 16))
    max_new = 6
    solo = [_solo(cfg, params, p, max_new) for p in prompts]

    eng = ServeEngine(cfg, params, slots=3, seq_budget=BUDGET,
                      buckets=BUCKETS)
    q = AdmissionQueue(buckets=BUCKETS)
    for i, p in enumerate(prompts):            # staggered arrivals
        q.submit(p, max_new, now=float(i))
    got, now = {}, 0.0
    while len(got) < len(prompts):
        for req in q.admit(now, len(eng.free_slots())):
            eng.insert(req, now)
        for r in eng.step(now):
            got[r.id] = r.tokens
        now += 1.0
    assert [got[i] for i in range(len(prompts))] == solo


def test_engine_matches_lockstep_baseline(qwen_params):
    """With bucket-exact prompts on an attention arch the slot engine is
    token-identical to the pre-subsystem whole-batch lockstep path."""
    B, S, gen = 3, 16, 8
    g = np.random.default_rng(0)
    tokens = g.integers(0, QWEN.vocab, size=(B, S))
    budget = S + gen
    base, times = lockstep_serve(QWEN, qwen_params,
                                 {"tokens": jnp.asarray(tokens, jnp.int32)},
                                 gen, budget)
    assert steady_ms_per_step(times) > 0.0
    base = np.asarray(base)

    eng = ServeEngine(QWEN, qwen_params, slots=B, seq_budget=budget,
                      buckets=(S,))
    for i in range(B):
        eng.insert(Request(id=i, tokens=tuple(int(t) for t in tokens[i]),
                           max_new_tokens=gen))
    got = {r.id: r.tokens for r in _drain(eng)}
    for i in range(B):
        assert got[i] == tuple(int(t) for t in base[i])


# ------------------------------------------------------------- no recompile --
def test_no_recompile_after_warmup(qwen_params):
    """Admission, eviction, and slot churn never trigger a recompile: after
    the first request of each bucket length, jit cache sizes are pinned."""
    eng = ServeEngine(QWEN, qwen_params, slots=2, seq_budget=BUDGET,
                      buckets=BUCKETS)
    warm = _prompts(QWEN.vocab, lens=(10, 17), seed=1)
    for i, p in enumerate(warm):
        eng.insert(Request(id=i, tokens=p, max_new_tokens=3))
    _drain(eng)
    pinned = eng.compile_counts()
    assert pinned["step"] == 1
    assert set(pinned["prefill"]) == {8, 16}

    # churn: 6 more requests across both buckets, arriving mid-flight
    for j, p in enumerate(_prompts(QWEN.vocab, lens=(9, 21, 8, 16, 30, 11),
                                   seed=2)):
        while not eng.free_slots():
            eng.step()
        eng.insert(Request(id=10 + j, tokens=p, max_new_tokens=2))
        eng.step()
    _drain(eng)
    assert eng.compile_counts() == pinned


def test_insert_rejects_over_budget(qwen_params):
    eng = ServeEngine(QWEN, qwen_params, slots=1, seq_budget=16,
                      buckets=(8,))
    with pytest.raises(ValueError, match="seq_budget"):
        eng.insert(Request(id=0, tokens=tuple(range(12)), max_new_tokens=8))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.insert(Request(id=1, tokens=(), max_new_tokens=2))


# ------------------------------------------------------------------ loadgen --
def test_loadgen_deterministic_and_accounted(qwen_params):
    spec = LoadSpec(n_requests=12, rate=6.0, prompt_len=(3, 30),
                    max_new=(2, 6), vocab=QWEN.vocab, seed=11)
    assert draw_arrivals(spec) == draw_arrivals(spec)   # seeded: identical

    eng = ServeEngine(QWEN, qwen_params, slots=3, seq_budget=BUDGET,
                      buckets=BUCKETS)
    q = AdmissionQueue(buckets=BUCKETS, timeout=60.0, max_queue=32)
    rep = run_load(eng, q, spec)
    assert rep["completed"] + rep["shed"] == spec.n_requests
    assert rep["tokens"] > 0 and rep["latency_p50_s"] > 0
    assert rep["latency_p99_s"] >= rep["latency_p50_s"]
    assert rep["compiles"]["step"] == 1


# ----------------------------------------------------------------- hot swap --
def test_hot_swap_from_live_fed_engine(rng):
    """Train-while-serving: a FedEngine LLM DSFL run hot-swaps the server's
    weights at every chunk boundary.  Responses decoded before the run carry
    version 0, responses after carry the final round number, and the swap
    adds zero compiled programs."""
    K, B, S = 2, 4, 32
    task = build_lm_task(seed=0, K=K, batch=B, seq=S, vocab=QWEN.vocab)
    hp = LLMDsflHP(lr=5e-3, rounds=2, seed=0, open_batch=B)
    algo = LLMDSFLAlgorithm(QWEN, hp)
    stacked = jax.vmap(lambda k: model_init(QWEN, k))(jax.random.split(rng, K))
    fed = FedEngine(algo)
    state = algo.init_from(stacked)

    srv = ServeEngine(QWEN, model_init(QWEN, rng), slots=2,
                      seq_budget=BUDGET, buckets=BUCKETS)
    prompt = _prompts(QWEN.vocab, lens=(12,))[0]

    srv.insert(Request(id=0, tokens=prompt, max_new_tokens=4))
    (before,) = _drain(srv)
    assert before.weights_version == 0
    pinned = srv.compile_counts()

    sync = attach(fed, srv, algo)
    state = fed.run(state, task, rounds=2)
    assert [r for r, _ in sync.swap_log] == [1, 2]
    assert all(dt >= 0 for _, dt in sync.swap_log)
    assert srv.version == 2

    srv.insert(Request(id=1, tokens=prompt, max_new_tokens=4))
    (after,) = _drain(srv)
    assert after.weights_version == 2
    assert srv.compile_counts() == pinned       # swap never recompiles

    # the served weights ARE the trained global model
    want, _ = algo.eval_params(state)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), want, srv.params)


def test_swap_mismatch_names_leaves(qwen_params):
    srv = ServeEngine(QWEN, qwen_params, slots=1, seq_budget=16,
                      buckets=(8,))
    bad = jax.tree.map(lambda a: a, qwen_params)
    key = sorted(bad)[0]
    bad[key] = jax.tree.map(lambda a: a[..., :1], bad[key])
    with pytest.raises(ValueError, match=key):
        srv.swap_weights(bad)


def test_swap_from_checkpoint(tmp_path, qwen_params):
    from repro.checkpoint import save_pytree
    srv = ServeEngine(QWEN, qwen_params, slots=1, seq_budget=16,
                      buckets=(8,))
    new = jax.tree.map(lambda a: a * 0.5, qwen_params)
    path = str(tmp_path / "weights.msgpack")
    save_pytree(path, new)
    dt = swap_from_checkpoint(srv, path, version=7)
    assert dt >= 0 and srv.version == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), new, srv.params)


def test_load_state_mismatch_names_leaves(rng, tmp_path):
    """A checkpoint saved from a different config fails loudly at load time
    with the offending leaves named, not later inside a jit."""
    K, B, S = 2, 4, 32
    hp = LLMDsflHP(lr=5e-3, rounds=1, seed=0, open_batch=B)
    algo = LLMDSFLAlgorithm(QWEN, hp)
    stacked = jax.vmap(lambda k: model_init(QWEN, k))(jax.random.split(rng, K))
    fed = FedEngine(algo)
    state = algo.init_from(stacked)
    path = str(tmp_path / "state.msgpack")
    fed.save_state(path, state)

    wrong = jax.tree.map(lambda a: a, state)
    with pytest.raises(ValueError, match="does not match"):
        like = jax.tree.map(
            lambda a: a[..., :1] if a.ndim > 1 else a, wrong)
        fed.load_state(path, like)


# ---------------------------------------------------------- queue invariants --
def test_bucket_of():
    assert bucket_of(20, (8, 16, 32)) == 16
    assert bucket_of(16, (8, 16, 32)) == 16
    assert bucket_of(5, (8, 16, 32)) == 5     # shorter than every bucket
    assert bucket_of(100, (8, 16, 32)) == 32


def test_queue_invariants_property():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    ops = st.lists(st.tuples(st.booleans(),           # submit vs admit
                             st.integers(1, 40),      # prompt len / slots
                             st.integers(0, 3)),      # clock increment
                   max_size=60)

    @settings(deadline=None, max_examples=80)
    @given(ops)
    def run(events):
        q = AdmissionQueue(buckets=(8, 16), timeout=4.0, max_queue=5)
        now, admitted = 0.0, []
        for is_submit, a, dt in events:
            now += dt * 0.75
            if is_submit:
                q.submit(tuple(range(a)), 4, now=now)
            else:
                free = a % 4
                got = q.admit(now, free)
                assert len(got) <= free          # never exceeds slot budget
                admitted.extend(got)
        q.shed_expired(now + 1e9)                # flush whatever remains
        assert len(q) == 0
        # exactly-once accounting: submitted == admitted + shed, no dupes
        ids = [r.id for r in admitted] + [r.id for r in q.shed]
        assert len(ids) == len(set(ids)) == q.n_submitted
        assert q.n_admitted == len(admitted)
        for r in q.shed:
            assert r.shed and r.tokens == ()
        # FIFO within each bucket: ids are issued in submit order
        per_bucket = {}
        for r in admitted:
            per_bucket.setdefault(bucket_of(r.prompt_len, (8, 16)),
                                  []).append(r.id)
        for got_ids in per_bucket.values():
            assert got_ids == sorted(got_ids)

    run()


def test_queue_timeout_and_overload_shed():
    q = AdmissionQueue(buckets=(8,), timeout=1.0, max_queue=2)
    q.submit((1, 2, 3), 4, now=0.0)
    q.submit((1, 2, 3), 4, now=0.1)
    q.submit((1, 2, 3), 4, now=0.2)              # over max_queue: shed now
    assert len(q.shed) == 1 and q.shed[0].shed
    assert q.admit(now=5.0, free_slots=4) == []  # both expired meanwhile
    assert len(q.shed) == 3
    assert q.n_submitted == 3 and len(q) == 0
