"""Observability smoke: one traced train-while-serving pass, end to end.

A DS-FL `FedEngine` trains with a `WeightSync` hot-swapping a `ServeEngine`
at every round boundary while requests flow through the `AdmissionQueue` —
the full stack — first untraced (warmup: every jit compiles), then again
with the tracer + metrics registry installed.  The smoke then asserts the
observability contracts CI cares about:

* the JSONL trace validates against the span/instant schema, carries a
  provenance stamp, and contains spans from >= 3 layers (engine / wire /
  serve / swap), and converts to a Perfetto-loadable trace_event file;
* **zero new XLA compiles** in the traced steady-state pass
  (`JitCacheWatch.assert_no_new_compiles`) — tracing never perturbs the
  jit caches, and the warmed-up stack never retraces;
* the metrics snapshot (counters/gauges/histograms + provenance) lands on
  disk and contains the engine/serve/swap series the run published.

Emits ``OBS_trace.jsonl``, ``OBS_trace.perfetto.json``,
``OBS_metrics.json`` (cwd) and returns CSV rows for `benchmarks.run`
(key ``obs``).

  PYTHONPATH=src python -m benchmarks.obs_smoke          # CI tier
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import FedEngine
from repro.core.llm_algorithms import LLMDSFLAlgorithm
from repro.core.llm_dsfl import LLMDsflHP
from repro.data.pipeline import build_lm_task
from repro.models.api import model_init
from repro.obs import (JitCacheWatch, MetricsRegistry, RunProvenance,
                       install_registry, trace_to)
from repro.obs.perfetto import to_perfetto, validate
from repro.serve import AdmissionQueue, ServeEngine, attach

OUT_TRACE = "OBS_trace.jsonl"
OUT_PERFETTO = "OBS_trace.perfetto.json"
OUT_METRICS = "OBS_metrics.json"
ARCH = "qwen1.5-4b"
BUCKETS = (8, 16, 32)
REQUIRED_LAYERS = ("engine", "wire", "serve", "swap")


def _serve_some(srv, queue, prompt, n=2, now=0.0):
    """Push ``n`` requests through queue -> engine to completion."""
    for i in range(n):
        queue.submit(prompt, 4, now=now)
    for req in queue.admit(now, len(srv.free_slots())):
        srv.insert(req, now)
    while srv.n_active:
        srv.step(now)
    return srv.pop_completed()


def _workload(fed, state, task, srv, queue, prompt, rounds):
    """One full pass: serve, measure the wire, train (swapping into the
    server every round), serve again on the new weights."""
    _serve_some(srv, queue, prompt)
    fed.measured_leg_bytes(state, task)          # the wire.measure span
    state = fed.run(state, task, rounds=rounds)  # swaps ride on_chunk
    _serve_some(srv, queue, prompt)
    return state


def run(fast: bool = True):
    """benchmarks.run entry: (name, us_per_call, derived) rows +
    OBS_* side effects."""
    rounds = 2
    K, B, S = 2, 4, 32
    cfg = get_config(ARCH).smoke()
    task = build_lm_task(seed=0, K=K, batch=B, seq=S, vocab=cfg.vocab)
    algo = LLMDSFLAlgorithm(cfg, LLMDsflHP(lr=5e-3, rounds=4 * rounds,
                                           seed=0, open_batch=B))
    stacked = jax.vmap(lambda k: model_init(cfg, k))(
        jax.random.split(jax.random.PRNGKey(1), K))
    fed = FedEngine(algo)
    state = algo.init_from(stacked)

    srv = ServeEngine(cfg, model_init(cfg, jax.random.PRNGKey(2)),
                      slots=2, seq_budget=64, buckets=BUCKETS)
    queue = AdmissionQueue(buckets=BUCKETS)
    attach(fed, srv, algo)
    rng = np.random.default_rng(5)
    prompt = tuple(int(x) for x in rng.integers(0, cfg.vocab, size=12))

    with JitCacheWatch() as watch:
        # warmup: every program on the path compiles here (recorded).  Two
        # passes, because the first run's output state differs in buffer
        # provenance from the freshly-initialized input, costing a one-time
        # re-specialization that the steady state never sees again.
        state = _workload(fed, state, task, srv, queue, prompt, rounds)
        state = _workload(fed, state, task, srv, queue, prompt, rounds)
        n_warm = watch.compiles()
        watch.mark()

        prov = RunProvenance.collect().asdict()
        reg = MetricsRegistry()
        prev = install_registry(reg)
        try:
            with trace_to(OUT_TRACE, provenance=prov) as tracer:
                state = _workload(fed, state, task, srv, queue, prompt,
                                  rounds)
            n_records = tracer.n_records
        finally:
            install_registry(prev)
        reg.to_json(OUT_METRICS, provenance=prov)

        # contract 1: the warmed-up, traced pass never recompiles
        watch.assert_no_new_compiles("in the traced steady-state pass")

    # contract 2: the trace validates and spans >= 3 instrumented layers
    summary = validate(OUT_TRACE, require_layers=REQUIRED_LAYERS)
    to_perfetto(OUT_TRACE, OUT_PERFETTO)

    # contract 3: the snapshot holds the published series + provenance
    with open(OUT_METRICS) as f:
        snap = json.load(f)
    assert snap["provenance"]["git_sha"] == prov["git_sha"], snap
    for series in ("engine.rounds", "serve.decode_steps", "serve.swaps",
                   "swap.latency_s", "queue.depth"):
        assert series in snap["metrics"], (
            f"metrics snapshot missing {series}: "
            f"{sorted(snap['metrics'])}")
    assert snap["metrics"]["serve.swaps"] == rounds, snap["metrics"]

    return [
        ("obs_trace_records", float(n_records),
         f"layers={'/'.join(summary['layers'])} spans={summary['spans']}"),
        ("obs_compiles_warmup", float(n_warm),
         f"engine={fed.compile_counts()['round_programs']}rnd "
         f"serve_step={srv.compile_counts()['step']}"),
        ("obs_compiles_after_warmup", float(len(watch.new_since_mark())),
         "traced steady state: must be 0"),
        ("obs_metrics_series", float(len(snap["metrics"])),
         f"snapshot={OUT_METRICS}"),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI tier (the only tier: this is a smoke)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for name, us, derived in run(fast=args.smoke):
        print(f"{name},{us:.1f},{derived}", flush=True)
    print(f"wrote {OUT_TRACE}, {OUT_PERFETTO}, {OUT_METRICS}")
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
