"""Paper Fig. 8: noisy-open-data attack — foreign images injected into the
open set; ERA degrades less than SA."""
from __future__ import annotations

from repro.data.pipeline import build_image_task
from .common import ExpConfig, run_dsfl, top_acc


def run(fast: bool = True):
    ec = ExpConfig(K=4 if fast else 10, rounds=3 if fast else 10,
                   open_batch=200)
    rows = []
    noises = (0, 400) if fast else (0, 400, 800, 1600)
    for n_noise in noises:
        task = build_image_task(seed=0, K=ec.K, n_private=800, n_open=400,
                                n_test=400, distribution="non_iid",
                                noisy_open=n_noise)
        for name in ("era", "sa"):
            ta = top_acc(run_dsfl(task, ec, name))
            rows.append((f"fig8/noise{n_noise}/{name}", 0.0,
                         f"top_acc={ta:.3f}"))
    return rows
