"""Wire-codec benchmark: encode/decode throughput of each `repro.core.wire`
codec on a paper-scale DS-FL upload, plus measured-vs-analytic byte counts
for all three algorithms through the unified `FedEngine`."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import wire
from repro.core.comm import fmt_bytes
from .common import (ExpConfig, comm_model, dsfl_engine, make_clients,
                     cnn_init, timed)
from repro.data.pipeline import build_image_task


def run(fast: bool = True):
    ec = ExpConfig(K=4 if fast else 10, rounds=1, open_batch=200 if fast
                   else 1000)
    task = build_image_task(seed=0, K=ec.K, n_private=400,
                            n_open=ec.open_batch, n_test=100,
                            distribution="non_iid")
    cm = comm_model(task, ec)
    n, C = ec.open_batch, task.n_classes
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(0), (n, C)), -1)

    rows = []
    codecs = [("dense_f32", wire.DenseF32Codec(), cm.dsfl_round()),
              ("fp16", wire.FP16Codec(), cm.dsfl_fp16_round()),
              ("topk", wire.TopKCodec(k=5, n_classes=C),
               cm.dsfl_topk_round(5))]
    for name, codec, analytic in codecs:
        enc = jax.jit(codec.encode)
        dec = jax.jit(codec.decode)
        us_e, payload = timed(enc, probs)
        us_d, _ = timed(dec, payload)
        measured = codec.payload_bytes(payload) * (ec.K + 1)
        ok = "OK" if measured == analytic else "MISMATCH"
        rows.append((f"wire/{name}_encode", us_e,
                     f"round={fmt_bytes(measured)} analytic="
                     f"{fmt_bytes(analytic)} {ok}"))
        rows.append((f"wire/{name}_decode", us_d, ""))

    # measured per-round bytes through the engine (the Table 1/2 cross-check)
    eng = dsfl_engine(task, ec)
    wk, sk = make_clients(jax.random.PRNGKey(0), ec.K)
    wg, sg = cnn_init(jax.random.PRNGKey(0))
    state = eng.algo.init_from(wk, sk, wg, sg)
    mb = eng.measured_round_bytes(state, task)
    rows.append(("wire/dsfl_engine_round_bytes", 0.0,
                 f"{fmt_bytes(mb)} (analytic {fmt_bytes(cm.dsfl_round())})"))
    return rows
