"""Benchmark harness — one module per paper table/figure + kernels/roofline.
Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # fast mode (~10 min CPU)
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale settings
  PYTHONPATH=src python -m benchmarks.run --only fig5,table4
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = [
    ("comm", "benchmarks.comm_cost"),            # Tables 1-2
    ("wire", "benchmarks.wire_bench"),           # measured codec bytes
    ("fig2", "benchmarks.fd_logit"),             # FD logit collapse
    ("fig3", "benchmarks.entropy_bench"),        # entropy traces (Figs 3/9)
    ("fig5", "benchmarks.accuracy_vs_comm"),     # acc vs comm + Table 3
    ("fig6", "benchmarks.temperature"),          # ERA temperature sweep
    ("fig7", "benchmarks.noisy_label"),          # noisy labels
    ("fig8", "benchmarks.noisy_open"),           # noisy open data
    ("table4", "benchmarks.poisoning"),          # model poisoning
    ("ttacc", "benchmarks.time_to_accuracy"),    # sim: acc vs wallclock/bytes
    ("engine", "benchmarks.engine_bench"),       # loop-vs-scan + weighted ERA
    ("serve", "benchmarks.serve_bench"),         # continuous batching + swap
    ("obs", "benchmarks.obs_smoke"),             # traced stack + no-recompile
    ("kernels", "benchmarks.kernels_bench"),     # Pallas kernels
    ("roofline", "benchmarks.roofline_report"),  # dry-run roofline table
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench keys to run")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for key, module_name in BENCHES:
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(module_name, fromlist=["run"])
            rows = mod.run(fast=not args.full)
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}", flush=True)
            print(f"# {key} done in {time.time()-t0:.0f}s", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {key} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
