"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json):
per (arch x shape x mesh) — the three terms, bottleneck, useful-FLOPs ratio.
Run ``python -m repro.launch.dryrun --all --mesh both`` first."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = "experiments/dryrun"


def load_records(tag_filter: str = ""):
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        name = os.path.basename(p)
        if tag_filter and tag_filter not in name:
            continue
        recs.append(r)
    return recs


def run(fast: bool = True):
    rows = []
    recs = load_records()
    if not recs:
        return [("roofline/missing", 0.0,
                 "run repro.launch.dryrun first")]
    ok = [r for r in recs if r.get("status") == "ok"]
    fail = [r for r in recs if r.get("status") == "fail"]
    skip = [r for r in recs if r.get("status") == "skipped"]
    rows.append(("roofline/summary", 0.0,
                 f"ok={len(ok)} fail={len(fail)} skipped={len(skip)}"))
    for r in ok:
        if "t_compute" not in r:
            continue
        dom = r["bottleneck"]
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            max(r["t_compute"], r["t_memory"], r["t_collective"]) * 1e6,
            f"tc={r['t_compute']*1e3:.2f}ms tm={r['t_memory']*1e3:.2f}ms "
            f"tx={r['t_collective']*1e3:.2f}ms dom={dom} "
            f"useful={r['useful_ratio']:.3f} "
            f"mem/dev={(r['memory_analysis']['argument_size'] + r['memory_analysis']['temp_size'])/1e9:.1f}GB"))
    for r in fail:
        rows.append((f"roofline/FAIL/{r['arch']}/{r['shape']}/{r['mesh']}",
                     0.0, r.get("error", "?")[:120]))
    return rows
