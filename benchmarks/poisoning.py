"""Paper Table 4: model-poisoning (Bagdasaryan et al. replacement) attack.

Main task: synthetic digits.  Backdoor task: the foreign 'fashion_noise'
family labeled with the attacker's target classes.  The malicious model w_x
is trained on both.  In FL the replacement upload (Eq. 19) makes the global
model equal w_x -> backdoor succeeds.  In DS-FL the attacker can only upload
logits of w_x, which the aggregation dilutes -> backdoor fails."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.client import LocalSpec, local_update, predict_probs
from repro.core.losses import accuracy
from repro.data.pipeline import build_image_task
from repro.data.synthetic import make_fashion_noise
from repro.optim import optimizers as opt_lib
from .common import APPLY, ExpConfig, cnn_init, run_dsfl, run_fl


def train_malicious(task, noise_x, noise_y, ec):
    """Attacker trains on digits + backdoor data jointly."""
    key = jax.random.PRNGKey(99)
    w, s = cnn_init(key)
    x = jnp.concatenate([task.x_clients.reshape((-1,) + task.x_clients.shape[2:]),
                         noise_x], 0)
    y = jnp.concatenate([task.y_clients.reshape(-1), noise_y], 0)
    opt = opt_lib.make("sgd", ec.lr)
    spec = LocalSpec(APPLY, opt, 8, ec.batch_size)
    o = opt.init(w)
    w, s, o, _ = jax.jit(lambda w, s, o, rk: local_update(
        spec, w, s, o, x, y, rk))(w, s, o, key)
    return w, s


def run(fast: bool = True):
    ec = ExpConfig(K=4 if fast else 10, rounds=4 if fast else 12,
                   open_batch=200, seed=3)
    task = build_image_task(seed=3, K=ec.K, n_private=800, n_open=400,
                            n_test=400, distribution="iid")
    kb = jax.random.PRNGKey(42)
    noise_x, noise_y = make_fashion_noise(kb, 800)
    bd_test_x, bd_test_y = make_fashion_noise(jax.random.fold_in(kb, 1), 400)
    w_x, s_x = train_malicious(task, noise_x, noise_y, ec)

    rows = []
    main_x = float(accuracy(APPLY(w_x, s_x, task.x_test, False)[0],
                            task.y_test))
    bd_x = float(accuracy(APPLY(w_x, s_x, bd_test_x, False)[0], bd_test_y))
    rows.append(("table4/malicious_model", 0.0,
                 f"main={main_x:.3f} backdoor={bd_x:.3f}"))

    # --- FL: replacement attack every 5 rounds (Eq. 17-19 net effect) ---
    def poison_fn(r, w0, s0):
        if r % 5 == 0:
            return w_x, s_x
        return w0, s0

    hist, (w0, s0) = run_fl(task, ec, poison_fn=poison_fn)
    main = float(accuracy(APPLY(w0, s0, task.x_test, False)[0], task.y_test))
    bd = float(accuracy(APPLY(w0, s0, bd_test_x, False)[0], bd_test_y))
    rows.append(("table4/fl_poisoned", 0.0,
                 f"main={main:.3f} backdoor={bd:.3f} (paper: 98.9/90.4)"))

    # --- DS-FL: attacker uploads w_x's logits ---
    def corrupt(probs, xo, rng):
        mal = predict_probs(APPLY, w_x, s_x, xo)
        return probs.at[0].set(mal)

    state_era = None
    for agg in ("sa", "era"):
        h, st = run_dsfl(task, ec, agg, corrupt=corrupt, return_state=True)
        if agg == "era":
            state_era = st
        rows.append((f"table4/dsfl_{agg}_main", 0.0,
                     f"main={max(x['test_acc'] for x in h):.3f}"))
    # backdoor accuracy of the DS-FL server model from the ERA run above
    wg, sg = state_era.server.params, state_era.server.model_state
    bd = float(accuracy(APPLY(wg, sg, bd_test_x, False)[0], bd_test_y))
    main = float(accuracy(APPLY(wg, sg, task.x_test, False)[0], task.y_test))
    rows.append(("table4/dsfl_era_server", 0.0,
                 f"main={main:.3f} backdoor={bd:.3f} (paper: 97.9/8.7)"))
    return rows
