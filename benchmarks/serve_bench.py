"""Serving benchmark: continuous-batching latency/throughput grid + live
weight hot-swap from a training `FedEngine`.

Grid: `repro.serve.ServeEngine` under the deterministic open-loop load
generator (`repro.serve.loadgen` — seeded, virtual-time, so the latency
percentiles are bit-reproducible across hosts) for >= 2 batch-slot counts
x >= 2 request rates.  Each cell reports p50/p99 request latency and
time-to-first-token in virtual seconds, throughput in generated tokens per
virtual second (and per wall second for a real-hardware number), and exact
shed accounting.  One engine per slot count, `reset()` between rates: the
decode step compiles once per slot count and the jit cache counts are
recorded to prove it.

Swap: a train-while-serving smoke — an LLM DS-FL `FedEngine` run with a
`WeightSync` attached hot-swaps the server's weights at every round
boundary; the measured swap latency (checkpointed params -> serving
buffers, block_until_ready) and the version stamps observed on responses
before/after land in the report.

Fusion: the same request trace served four ways — single-insert vs
batched same-bucket prefill, and fused decode chunks d in {1, 4, 16} —
with every configuration's tokens asserted identical in the same run.
The d=16 run pays one host sync per 16 decode steps instead of one per
token, and the batched insert one compiled prefill shot per same-bucket
group instead of one per request; the section records wall time, virtual
throughput, dispatch counts, and the compile sets (the prefill compile
set must be inside the bucket set — the 5/7 non-bucket leak regression).

Emits ``BENCH_serve.json`` (cwd) and returns CSV rows for `benchmarks.run`
(key ``serve``).

  PYTHONPATH=src python -m benchmarks.serve_bench --smoke   # CI tier
  PYTHONPATH=src python -m benchmarks.serve_bench           # fuller grid
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import FedEngine
from repro.core.llm_algorithms import LLMDSFLAlgorithm
from repro.core.llm_dsfl import LLMDsflHP
from repro.data.pipeline import build_lm_task
from repro.launch import platform
from repro.models.api import model_init
from repro.obs import RunProvenance
from repro.serve import (AdmissionQueue, LoadSpec, Request, ServeEngine,
                         attach, run_load)

OUT_JSON = "BENCH_serve.json"
ARCH = "qwen1.5-4b"
BUCKETS = (8, 16, 32)
BUDGET = 64
STEP_COST = 0.01      # virtual seconds per decode step
PREFILL_COST = 0.05   # virtual seconds per prefill-insert


def bench_grid(fast: bool) -> dict:
    """Latency/throughput for every (slots, rate) cell.  The high-rate cells
    deliberately exceed the virtual service capacity so the queue's
    timeout/shed policy shows up in the numbers instead of an unbounded
    backlog."""
    slot_counts = (2, 4) if fast else (2, 4, 8)
    rates = (4.0, 16.0) if fast else (4.0, 16.0, 64.0)
    n_requests = 32 if fast else 128

    cfg = get_config(ARCH).smoke()
    params = model_init(cfg, jax.random.PRNGKey(0))
    cells = {}
    for slots in slot_counts:
        engine = ServeEngine(cfg, params, slots=slots, seq_budget=BUDGET,
                             buckets=BUCKETS)
        # warmup: compile the decode step and every prefill bucket (incl.
        # the short-prompt bucket-1 fallback), so cell wall-times measure
        # steady-state serving, not XLA
        for i, n in enumerate(engine.buckets):
            while not engine.free_slots():
                engine.step()
            engine.insert(Request(id=-1 - i, tokens=tuple(range(1, n + 1)),
                                  max_new_tokens=1))
        while engine.n_active:
            engine.step()
        engine.pop_completed()
        for rate in rates:
            engine.reset()
            queue = AdmissionQueue(buckets=engine.buckets, timeout=2.0,
                                   max_queue=4 * slots)
            spec = LoadSpec(n_requests=n_requests, rate=rate,
                            prompt_len=(4, 40), max_new=(4, 12),
                            vocab=cfg.vocab, seed=17)
            rep = run_load(engine, queue, spec,
                           step_cost=STEP_COST, prefill_cost=PREFILL_COST)
            rep.pop("responses")
            assert rep["completed"] + rep["shed"] == n_requests, rep
            cells[f"slots{slots}_rate{rate:g}"] = {
                "slots": slots, "rate": rate, "n_requests": n_requests,
                **{k: v for k, v in rep.items()}}
        # the whole rate sweep rode one decode-step compile
        assert engine.compile_counts()["step"] == 1, engine.compile_counts()
    return {"arch": ARCH, "backend": jax.default_backend(),
            "step_cost_virtual_s": STEP_COST,
            "prefill_cost_virtual_s": PREFILL_COST, "cells": cells}


FUSION_CONFIGS = {
    # name -> (decode_chunk, batch_insert)
    "single_d1": (1, False),
    "batched_d1": (1, True),
    "batched_d4": (4, True),
    "batched_d16": (16, True),
}


def bench_fusion(fast: bool) -> dict:
    """The fused fast paths on ONE request trace: single-insert vs batched
    same-bucket prefill, and decode chunks d in {1, 4, 16}.  The queue is
    unbounded (no shed) so every configuration serves the identical
    request set, and the generated tokens are asserted identical across
    all configurations in the same run — the fusion is pure schedule, zero
    semantics.  Wall time is best-of-``reps`` per configuration with the
    jit caches warmed by a throwaway first pass."""
    slots = 4
    n_requests = 24 if fast else 96
    reps = 2 if fast else 3
    cfg = get_config(ARCH).smoke()
    params = model_init(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, slots=slots, seq_budget=BUDGET,
                         buckets=BUCKETS)
    # uniform bucket-length prompts and a generation length whose decode
    # step count (max_new - 1 = 32) is chunk-aligned for d in {4, 16}: the
    # regime the fusion targets (long steady decodes), where a finished
    # lane never idles inside a chunk it didn't need.  Ragged short
    # generations are the grid bench's territory.
    spec = LoadSpec(n_requests=n_requests, rate=8.0, prompt_len=(8, 8),
                    max_new=(33, 33), vocab=cfg.vocab, seed=23)

    def one_run(decode_chunk, batch_insert):
        engine.reset()
        queue = AdmissionQueue(buckets=engine.buckets)   # unbounded: no shed
        steps0, disp0, shots0 = (engine.n_steps, engine.n_dispatches,
                                 engine.n_prefill_shots)
        rep = run_load(engine, queue, spec,
                       step_cost=STEP_COST, prefill_cost=PREFILL_COST,
                       decode_chunk=decode_chunk, batch_insert=batch_insert)
        tokens = {r.id: r.tokens for r in rep.pop("responses")}
        assert rep["shed"] == 0 and rep["completed"] == n_requests, rep
        rep["decode_steps"] = engine.n_steps - steps0
        rep["decode_dispatches"] = engine.n_dispatches - disp0
        rep["prefill_shots"] = engine.n_prefill_shots - shots0
        return rep, tokens

    cells, tokens_by_config = {}, {}
    for name, (d, batched) in FUSION_CONFIGS.items():
        best, tokens = None, None
        for _ in range(1 + reps):       # first pass warms the jit caches
            rep, tokens = one_run(d, batched)
            if best is None or rep["wall_s"] < best["wall_s"]:
                best = rep
        tokens_by_config[name] = tokens
        cells[name] = {
            "decode_chunk": d, "batch_insert": batched,
            "n_requests": n_requests, "tokens": best["tokens"],
            "wall_s": best["wall_s"],
            "makespan_virtual_s": best["makespan_virtual_s"],
            "throughput_tok_per_virtual_s":
                best["throughput_tok_per_virtual_s"],
            "throughput_tok_per_wall_s": best["throughput_tok_per_wall_s"],
            "decode_steps": best["decode_steps"],
            "decode_dispatches": best["decode_dispatches"],
            "prefill_shots": best["prefill_shots"],
        }
    base = tokens_by_config["single_d1"]
    identical = all(toks == base for toks in tokens_by_config.values())
    assert identical, "fused paths changed tokens"
    # the bucket-leak regression: every compiled prefill length (single and
    # batched) must be a bucket — lengths like 5 and 7 must never compile
    compiles = engine.compile_counts()
    prefill_lens = set(compiles["prefill"]) | {
        int(k.split("x")[0]) for k in compiles["prefill_batch"]}
    assert prefill_lens <= set(engine.buckets), (prefill_lens, engine.buckets)
    return {"arch": ARCH, "slots": slots, "reps": reps,
            "step_cost_virtual_s": STEP_COST,
            "prefill_cost_virtual_s": PREFILL_COST,
            "tokens_identical": identical,
            "compiles": compiles, "buckets": list(engine.buckets),
            "cells": cells}


def bench_swap(fast: bool) -> dict:
    """Train-while-serving: measured hot-swap latency from a live FedEngine
    LLM DS-FL run, plus the version stamps a client actually observes."""
    K, B, S = 2, 4, 32
    rounds = 2 if fast else 4
    cfg = get_config(ARCH).smoke()
    task = build_lm_task(seed=0, K=K, batch=B, seq=S, vocab=cfg.vocab)
    hp = LLMDsflHP(lr=5e-3, rounds=rounds, seed=0, open_batch=B)
    algo = LLMDSFLAlgorithm(cfg, hp)
    stacked = jax.vmap(lambda k: model_init(cfg, k))(
        jax.random.split(jax.random.PRNGKey(1), K))
    fed = FedEngine(algo)
    state = algo.init_from(stacked)

    srv = ServeEngine(cfg, model_init(cfg, jax.random.PRNGKey(2)),
                      slots=2, seq_budget=BUDGET, buckets=BUCKETS)
    rng = np.random.default_rng(5)
    prompt = tuple(int(x) for x in rng.integers(0, cfg.vocab, size=12))

    def one_response(rid):
        srv.insert(Request(id=rid, tokens=prompt, max_new_tokens=4))
        while srv.n_active:
            srv.step()
        (r,) = srv.pop_completed()
        return r

    v_before = one_response(0).weights_version
    compiles_before = srv.compile_counts()
    sync = attach(fed, srv, algo)
    t0 = time.perf_counter()
    fed.run(state, task, rounds=rounds)
    train_wall = time.perf_counter() - t0
    v_after = one_response(1).weights_version

    swaps_ms = [1e3 * dt for _, dt in sync.swap_log]
    return {"arch": ARCH, "clients": K, "rounds": rounds,
            "train_wall_s": train_wall,
            "n_swaps": len(sync.swap_log),
            "swap_ms_mean": float(np.mean(swaps_ms)),
            "swap_ms_max": float(np.max(swaps_ms)),
            "swap_rounds": [r for r, _ in sync.swap_log],
            "version_before": v_before, "version_after": v_after,
            "recompiles_from_swap":
                srv.compile_counts() != compiles_before}


def _sec(v) -> str:
    """Format a latency percentile; empty series are None (JSON null), not
    a -1.0 sentinel."""
    return "n/a" if v is None else f"{v:.3f}s"


def run(fast: bool = True):
    """benchmarks.run entry: (name, us_per_call, derived) rows +
    BENCH_serve.json side effect."""
    grid = bench_grid(fast)
    fusion = bench_fusion(fast)
    swap = bench_swap(fast)
    with open(OUT_JSON, "w") as f:
        # provenance header: which commit/jax/backend produced these numbers
        json.dump({"provenance": RunProvenance.collect().asdict(),
                   "grid": grid, "fusion": fusion, "swap": swap}, f, indent=2)

    rows = []
    for key, c in grid["cells"].items():
        # us_per_call column = measured wall time per generated token
        tok_us = (1e6 * c["wall_s"] / c["tokens"]) if c["tokens"] else -1.0
        rows.append((f"serve_{key}", tok_us,
                     f"p50={_sec(c['latency_p50_s'])} "
                     f"p99={_sec(c['latency_p99_s'])}(virtual) "
                     f"tok/s={c['throughput_tok_per_virtual_s']:.1f} "
                     f"shed={c['shed']}/{c['n_requests']}"))
    for key, c in fusion["cells"].items():
        tok_us = (1e6 * c["wall_s"] / c["tokens"]) if c["tokens"] else -1.0
        rows.append((f"serve_fusion_{key}", tok_us,
                     f"chunk={c['decode_chunk']} "
                     f"batch_insert={c['batch_insert']} "
                     f"dispatches={c['decode_dispatches']} "
                     f"prefill_shots={c['prefill_shots']} "
                     f"wall={c['wall_s']:.3f}s"))
    rows.append(("serve_weight_swap", 1e3 * swap["swap_ms_mean"],
                 f"max={swap['swap_ms_max']:.1f}ms n={swap['n_swaps']} "
                 f"v{swap['version_before']}->v{swap['version_after']} "
                 f"recompiles={swap['recompiles_from_swap']}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI tier: 2x2 grid, 32 requests/cell, 2 rounds of "
                         "train-while-serving; asserts the report is "
                         "complete, the swap recompile-free, and the fused "
                         "paths token-identical and faster")
    platform.add_args(ap)
    args = ap.parse_args(argv)
    # preset before backend init: XLA_FLAGS are read once
    platform.from_args(args)
    print("name,us_per_call,derived")
    for name, us, derived in run(fast=args.smoke):
        print(f"{name},{us:.1f},{derived}", flush=True)
    with open(OUT_JSON) as f:
        bench = json.load(f)
    cells, swap = bench["grid"]["cells"], bench["swap"]
    fusion = bench["fusion"]
    print(f"wrote {OUT_JSON}: {len(cells)} grid cells, "
          f"{len(fusion['cells'])} fusion configs, "
          f"{swap['n_swaps']} swaps ({swap['swap_ms_mean']:.1f} ms mean)")
    if args.smoke:
        slot_counts = {c["slots"] for c in cells.values()}
        rate_counts = {c["rate"] for c in cells.values()}
        assert len(slot_counts) >= 2 and len(rate_counts) >= 2, (
            f"grid too small: slots={slot_counts} rates={rate_counts}")
        for key, c in cells.items():
            assert c["completed"] + c["shed"] == c["n_requests"], (key, c)
            assert c["completed"] == 0 or c["latency_p99_s"] >= \
                c["latency_p50_s"], (key, c)
        assert swap["n_swaps"] >= 2, swap
        assert not swap["recompiles_from_swap"], swap
        assert swap["version_after"] == swap["rounds"], swap
        # fusion: tokens identical across every config (asserted again here
        # from the written report), batched prefill at least matches the
        # single-insert virtual throughput, and the 16-step fused chunk
        # beats per-token dispatch on wall time for the same trace
        fc = fusion["cells"]
        assert fusion["tokens_identical"], fusion
        assert fc["batched_d1"]["throughput_tok_per_virtual_s"] >= \
            fc["single_d1"]["throughput_tok_per_virtual_s"], fc
        assert fc["batched_d16"]["wall_s"] < fc["batched_d1"]["wall_s"], fc
        assert fc["batched_d16"]["decode_dispatches"] < \
            fc["batched_d1"]["decode_dispatches"], fc
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
