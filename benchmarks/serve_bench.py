"""Serving benchmark: continuous-batching latency/throughput grid + live
weight hot-swap from a training `FedEngine`.

Grid: `repro.serve.ServeEngine` under the deterministic open-loop load
generator (`repro.serve.loadgen` — seeded, virtual-time, so the latency
percentiles are bit-reproducible across hosts) for >= 2 batch-slot counts
x >= 2 request rates.  Each cell reports p50/p99 request latency and
time-to-first-token in virtual seconds, throughput in generated tokens per
virtual second (and per wall second for a real-hardware number), and exact
shed accounting.  One engine per slot count, `reset()` between rates: the
decode step compiles once per slot count and the jit cache counts are
recorded to prove it.

Swap: a train-while-serving smoke — an LLM DS-FL `FedEngine` run with a
`WeightSync` attached hot-swaps the server's weights at every round
boundary; the measured swap latency (checkpointed params -> serving
buffers, block_until_ready) and the version stamps observed on responses
before/after land in the report.

Emits ``BENCH_serve.json`` (cwd) and returns CSV rows for `benchmarks.run`
(key ``serve``).

  PYTHONPATH=src python -m benchmarks.serve_bench --smoke   # CI tier
  PYTHONPATH=src python -m benchmarks.serve_bench           # fuller grid
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import FedEngine
from repro.core.llm_algorithms import LLMDSFLAlgorithm
from repro.core.llm_dsfl import LLMDsflHP
from repro.data.pipeline import build_lm_task
from repro.models.api import model_init
from repro.obs import RunProvenance
from repro.serve import (AdmissionQueue, LoadSpec, Request, ServeEngine,
                         attach, run_load)

OUT_JSON = "BENCH_serve.json"
ARCH = "qwen1.5-4b"
BUCKETS = (8, 16, 32)
BUDGET = 64
STEP_COST = 0.01      # virtual seconds per decode step
PREFILL_COST = 0.05   # virtual seconds per prefill-insert


def bench_grid(fast: bool) -> dict:
    """Latency/throughput for every (slots, rate) cell.  The high-rate cells
    deliberately exceed the virtual service capacity so the queue's
    timeout/shed policy shows up in the numbers instead of an unbounded
    backlog."""
    slot_counts = (2, 4) if fast else (2, 4, 8)
    rates = (4.0, 16.0) if fast else (4.0, 16.0, 64.0)
    n_requests = 32 if fast else 128

    cfg = get_config(ARCH).smoke()
    params = model_init(cfg, jax.random.PRNGKey(0))
    cells = {}
    for slots in slot_counts:
        engine = ServeEngine(cfg, params, slots=slots, seq_budget=BUDGET,
                             buckets=BUCKETS)
        # warmup: compile the decode step and every prefill bucket, so cell
        # wall-times measure steady-state serving, not XLA
        for i, n in enumerate(BUCKETS):
            while not engine.free_slots():
                engine.step()
            engine.insert(Request(id=-1 - i, tokens=tuple(range(1, n + 1)),
                                  max_new_tokens=1))
        while engine.n_active:
            engine.step()
        engine.pop_completed()
        for rate in rates:
            engine.reset()
            queue = AdmissionQueue(buckets=BUCKETS, timeout=2.0,
                                   max_queue=4 * slots)
            spec = LoadSpec(n_requests=n_requests, rate=rate,
                            prompt_len=(4, 40), max_new=(4, 12),
                            vocab=cfg.vocab, seed=17)
            rep = run_load(engine, queue, spec,
                           step_cost=STEP_COST, prefill_cost=PREFILL_COST)
            rep.pop("responses")
            assert rep["completed"] + rep["shed"] == n_requests, rep
            cells[f"slots{slots}_rate{rate:g}"] = {
                "slots": slots, "rate": rate, "n_requests": n_requests,
                **{k: v for k, v in rep.items()}}
        # the whole rate sweep rode one decode-step compile
        assert engine.compile_counts()["step"] == 1, engine.compile_counts()
    return {"arch": ARCH, "backend": jax.default_backend(),
            "step_cost_virtual_s": STEP_COST,
            "prefill_cost_virtual_s": PREFILL_COST, "cells": cells}


def bench_swap(fast: bool) -> dict:
    """Train-while-serving: measured hot-swap latency from a live FedEngine
    LLM DS-FL run, plus the version stamps a client actually observes."""
    K, B, S = 2, 4, 32
    rounds = 2 if fast else 4
    cfg = get_config(ARCH).smoke()
    task = build_lm_task(seed=0, K=K, batch=B, seq=S, vocab=cfg.vocab)
    hp = LLMDsflHP(lr=5e-3, rounds=rounds, seed=0, open_batch=B)
    algo = LLMDSFLAlgorithm(cfg, hp)
    stacked = jax.vmap(lambda k: model_init(cfg, k))(
        jax.random.split(jax.random.PRNGKey(1), K))
    fed = FedEngine(algo)
    state = algo.init_from(stacked)

    srv = ServeEngine(cfg, model_init(cfg, jax.random.PRNGKey(2)),
                      slots=2, seq_budget=BUDGET, buckets=BUCKETS)
    rng = np.random.default_rng(5)
    prompt = tuple(int(x) for x in rng.integers(0, cfg.vocab, size=12))

    def one_response(rid):
        srv.insert(Request(id=rid, tokens=prompt, max_new_tokens=4))
        while srv.n_active:
            srv.step()
        (r,) = srv.pop_completed()
        return r

    v_before = one_response(0).weights_version
    compiles_before = srv.compile_counts()
    sync = attach(fed, srv, algo)
    t0 = time.perf_counter()
    fed.run(state, task, rounds=rounds)
    train_wall = time.perf_counter() - t0
    v_after = one_response(1).weights_version

    swaps_ms = [1e3 * dt for _, dt in sync.swap_log]
    return {"arch": ARCH, "clients": K, "rounds": rounds,
            "train_wall_s": train_wall,
            "n_swaps": len(sync.swap_log),
            "swap_ms_mean": float(np.mean(swaps_ms)),
            "swap_ms_max": float(np.max(swaps_ms)),
            "swap_rounds": [r for r, _ in sync.swap_log],
            "version_before": v_before, "version_after": v_after,
            "recompiles_from_swap":
                srv.compile_counts() != compiles_before}


def run(fast: bool = True):
    """benchmarks.run entry: (name, us_per_call, derived) rows +
    BENCH_serve.json side effect."""
    grid = bench_grid(fast)
    swap = bench_swap(fast)
    with open(OUT_JSON, "w") as f:
        # provenance header: which commit/jax/backend produced these numbers
        json.dump({"provenance": RunProvenance.collect().asdict(),
                   "grid": grid, "swap": swap}, f, indent=2)

    rows = []
    for key, c in grid["cells"].items():
        # us_per_call column = measured wall time per generated token
        tok_us = (1e6 * c["wall_s"] / c["tokens"]) if c["tokens"] else -1.0
        rows.append((f"serve_{key}", tok_us,
                     f"p50={c['latency_p50_s']:.3f}s "
                     f"p99={c['latency_p99_s']:.3f}s(virtual) "
                     f"tok/s={c['throughput_tok_per_virtual_s']:.1f} "
                     f"shed={c['shed']}/{c['n_requests']}"))
    rows.append(("serve_weight_swap", 1e3 * swap["swap_ms_mean"],
                 f"max={swap['swap_ms_max']:.1f}ms n={swap['n_swaps']} "
                 f"v{swap['version_before']}->v{swap['version_after']} "
                 f"recompiles={swap['recompiles_from_swap']}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI tier: 2x2 grid, 32 requests/cell, 2 rounds of "
                         "train-while-serving; asserts the report is "
                         "complete and swap-free of recompiles")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for name, us, derived in run(fast=args.smoke):
        print(f"{name},{us:.1f},{derived}", flush=True)
    with open(OUT_JSON) as f:
        bench = json.load(f)
    cells, swap = bench["grid"]["cells"], bench["swap"]
    print(f"wrote {OUT_JSON}: {len(cells)} grid cells, "
          f"{swap['n_swaps']} swaps ({swap['swap_ms_mean']:.1f} ms mean)")
    if args.smoke:
        slot_counts = {c["slots"] for c in cells.values()}
        rate_counts = {c["rate"] for c in cells.values()}
        assert len(slot_counts) >= 2 and len(rate_counts) >= 2, (
            f"grid too small: slots={slot_counts} rates={rate_counts}")
        for key, c in cells.items():
            assert c["completed"] + c["shed"] == c["n_requests"], (key, c)
            assert c["completed"] == 0 or c["latency_p99_s"] >= \
                c["latency_p50_s"], (key, c)
        assert swap["n_swaps"] >= 2, swap
        assert not swap["recompiles_from_swap"], swap
        assert swap["version_after"] == swap["rounds"], swap
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
