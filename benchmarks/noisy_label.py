"""Paper Fig. 7: noisy-label attack — Top-Accuracy vs #noised classes C for
DS-FL(ERA) / DS-FL(SA) / FL.  All clients relabel C source classes (IID)."""
from __future__ import annotations

import jax

from repro.core.attacks import apply_noisy_labels
from repro.data.pipeline import build_image_task
from .common import ExpConfig, run_dsfl, run_fl, top_acc


def run(fast: bool = True):
    ec = ExpConfig(K=4 if fast else 10, rounds=3 if fast else 10,
                   open_batch=200)
    rows = []
    Cs = (0, 4) if fast else (0, 2, 4, 6)
    for C in Cs:
        task = build_image_task(seed=0, K=ec.K, n_private=800, n_open=400,
                                n_test=400, distribution="iid")
        if C:
            task.y_clients = apply_noisy_labels(
                jax.random.PRNGKey(7), task.y_clients, task.n_classes, C)
        for name, runner in [("era", lambda: run_dsfl(task, ec, "era")),
                             ("sa", lambda: run_dsfl(task, ec, "sa")),
                             ("fl", lambda: run_fl(task, ec)[0])]:
            ta = top_acc(runner())
            rows.append((f"fig7/C{C}/{name}", 0.0, f"top_acc={ta:.3f}"))
    return rows
