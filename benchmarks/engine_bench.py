"""Engine hot-loop benchmark: per-round wallclock of the Python loop vs the
compiled `chunk_rounds` lax.scan (chunk 1/8/32), participation-sparse vs
dense-masked rounds at fraction 0.1/0.5/1.0, cohort-resident round cost vs
fleet size K at fixed cohort m (flat in K — the million-client headline),
and einsum+softmax vs the fused weighted-ERA Pallas kernel — the hot paths
this repo's time-to-accuracy claims ride on.

Emits ``BENCH_engine.json`` (cwd) so the perf trajectory is recorded
per-commit, and returns CSV rows for `benchmarks.run` (key ``engine``).

  PYTHONPATH=src python -m benchmarks.engine_bench --smoke   # CI tier
  PYTHONPATH=src python -m benchmarks.engine_bench           # fuller run

The smoke tier asserts two headlines: scanning 32 rounds per dispatch beats
the per-round loop on the small-model config (where host overhead
dominates), and the participation-sparse round beats the dense masked round
>= 3x at 10% participation (K = 64) while producing a bitwise-identical
history.  Kernel timings are tagged with their interpret mode: on CPU the
Pallas kernels run *interpreted*, so ``kernel_us`` there is not comparable
to the compiled einsum — only the TPU/GPU numbers are a real comparison.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core.algorithms import DSFLAlgorithm
from repro.core.cohort import ClientStore
from repro.core.engine import FedEngine
from repro.core.protocol import DSFLConfig
from repro.data.pipeline import SyntheticProvider, build_image_task
from repro.models.smallnets import apply_tiny_mlp, init_tiny_mlp
from repro.obs import RunProvenance
from repro.sim import ClientPopulation, CohortRunner, SyncScheduler

CHUNKS = (1, 8, 32)
FRACTIONS = (0.1, 0.5, 1.0)
POPULATIONS = (1_000, 10_000, 100_000)
POPULATIONS_FULL = (10_000, 100_000, 1_000_000)
OUT_JSON = "BENCH_engine.json"


def _block(state):
    jax.block_until_ready(jax.tree.leaves(state))


def bench_loop_vs_scan(fast: bool) -> dict:
    """Per-round wallclock of run(rounds=R) at chunk_rounds 1/8/32 on the
    paper-scale tiny-MLP config (the regime the benchmarks actually run,
    where per-round compute is small and dispatch overhead is visible)."""
    K, R = (8, 32) if fast else (16, 96)
    task = build_image_task(seed=0, K=K, n_private=40 * K, n_open=80,
                            n_test=40, distribution="non_iid")
    hp = DSFLConfig(rounds=R, local_epochs=1, distill_epochs=1,
                    batch_size=20, open_batch=40, aggregation="era")
    algo = DSFLAlgorithm(apply_tiny_mlp, hp)
    eng = FedEngine(algo)          # shared: jit caches persist across chunks

    out = {}
    for chunk in CHUNKS:
        state = eng.init(lambda k: init_tiny_mlp(k), task)
        # warmup: compile the round / the chunk driver (and the tail chunk)
        state = eng.run(state, task, rounds=R, chunk_rounds=chunk)
        _block(state)
        state = eng.init(lambda k: init_tiny_mlp(k), task)
        t0 = time.perf_counter()
        state = eng.run(state, task, rounds=R, chunk_rounds=chunk)
        _block(state)
        out[f"chunk{chunk}"] = (time.perf_counter() - t0) / R * 1e6
    return {"rounds": R, "clients": K, "per_round_us": out,
            "speedup_vs_loop": {k: out["chunk1"] / v
                                for k, v in out.items()}}


def bench_participation(fast: bool) -> dict:
    """Participation-sparse vs dense-masked per-round wallclock on a
    K-client fleet at fraction 0.1/0.5/1.0 — the ~K/m compute reduction the
    sparse plane exists for.  Both paths run the identical (rounds, K) mask
    plan through the compiled scan; the sparse run's history must be
    bitwise identical to the dense one (asserted here, every run)."""
    K, R, chunk, reps = (64, 8, 4, 3) if fast else (64, 24, 8, 5)
    task = build_image_task(seed=0, K=K, n_private=80 * K, n_open=80,
                            n_test=40, distribution="non_iid")
    hp = DSFLConfig(rounds=R, local_epochs=1, distill_epochs=1,
                    batch_size=20, open_batch=40, aggregation="era")
    algo = DSFLAlgorithm(apply_tiny_mlp, hp)
    eng = FedEngine(algo)          # shared jit caches across configs

    out = {}
    for frac in FRACTIONS:
        m = max(1, math.ceil(frac * K))
        rs = np.random.default_rng(17)
        mask = np.zeros((R, K), np.float32)
        for r in range(R):         # exactly m participants per round
            mask[r, rs.choice(K, size=m, replace=False)] = 1.0
        plan = {"mask": jnp.asarray(mask)}

        def one_run(budget):
            state = eng.init(lambda k: init_tiny_mlp(k), task)
            t0 = time.perf_counter()
            state = eng.run(state, task, rounds=R, chunk_rounds=chunk,
                            ctx_plan=plan, active_budget=budget)
            _block(state)
            return (time.perf_counter() - t0) / R * 1e6, list(eng.history)

        if m < K:
            budgets = (None, m)
            hists = [one_run(b)[1] for b in budgets]   # warmup: compile both
            bitwise = hists[1] == hists[0]
            # dense-vs-sparse compares two *different compiled programs*
            # (K-lane vs m-lane vmaps): that cross-program pin is guaranteed
            # on the single-device tier, but forcing fake host devices
            # (--xla_force_host_platform_device_count) shifts the CPU
            # client's codegen budget and can retile the two programs'
            # reductions differently — last-ULP drift that exists at the
            # seed commit, independent of the schedule.  Hard-assert where
            # it is a house invariant; record the verdict honestly (for the
            # uploaded JSON) where it is platform-dependent.  Schedule
            # parity (serialized vs pipelined, same program pieces) is
            # asserted on EVERY tier in bench_overlap.
            if jax.device_count() == 1:
                assert bitwise, (
                    f"sparse round history diverged from dense at "
                    f"fraction {frac}")
            elif not bitwise:
                print(f"  [participation] fraction {frac}: dense/sparse "
                      f"last-ULP drift on {jax.device_count()}-device tier "
                      f"(known cross-program codegen variance; recorded)")
            # interleaved best-of-reps: alternating runs cancel cache-warmth
            # drift between the dense and sparse measurements
            dense_us, sparse_us = (min(us) for us in zip(
                *[[one_run(b)[0] for b in budgets] for _ in range(reps)]))
        else:
            # budget >= K degrades to the dense path: measuring a second leg
            # would only record dense-vs-dense noise — run dense once
            one_run(None)                              # warmup
            dense_us = sparse_us = min(one_run(None)[0] for _ in range(reps))
            bitwise = True
        out[f"fraction{frac}"] = {
            "budget": m, "dense_us": dense_us, "sparse_us": sparse_us,
            "speedup": dense_us / sparse_us, "bitwise_identical": bitwise,
            "sparse_active": m < K}
    return {"clients": K, "rounds": R, "chunk_rounds": chunk, **out}


def bench_population_scaling(fast: bool) -> dict:
    """The million-client headline: per-round wallclock and resident
    client-state bytes of a `CohortRunner` fleet as K grows at a *fixed*
    cohort size m — both must be flat in K (nothing in the cohort-resident
    hot path is O(K): O(m log K) participation draws, an O(S) device slab,
    an O(#touched) host store, per-id synthetic data).  The O(K) pieces —
    fleet profiles, the provider's key — are one-time setup, excluded from
    the per-round timing and from the resident-state number."""
    Ks = POPULATIONS if fast else POPULATIONS_FULL
    m, R, chunk = (8, 6, 3) if fast else (50, 8, 4)
    hp = DSFLConfig(rounds=R + 2 * chunk, local_epochs=1, distill_epochs=1,
                    batch_size=10, open_batch=40, aggregation="era")
    out = {"cohort": m, "rounds": R, "chunk_rounds": chunk}
    for K in Ks:
        algo = DSFLAlgorithm(apply_tiny_mlp, hp)
        eng = FedEngine(algo)
        prov = SyntheticProvider(seed=0, n_clients=K, n_per_client=10,
                                 n_open=40)
        sched = SyncScheduler(ClientPopulation.lognormal(0, K),
                              fraction=m / K)
        rng0 = jax.random.PRNGKey(hp.seed)
        store = ClientStore(lambda ids, a=algo, k=K:
                            a.init_cohort(rng0, init_tiny_mlp, ids, k))
        runner = CohortRunner(engine=eng, scheduler=sched, provider=prov,
                              store=store, seed=0)
        # two warmup chunks compile the slab round AND reach the lazy-init
        # steady state (S is fixed across chunks, so one compile serves all)
        state = runner.run(algo.init_server(rng0, init_tiny_mlp),
                           rounds=2 * chunk, chunk_rounds=chunk)
        _block(state)
        t0 = time.perf_counter()
        state = runner.run(state, rounds=R, chunk_rounds=chunk)
        _block(state)
        out[f"K{K}"] = {
            "per_round_us": (time.perf_counter() - t0) / R * 1e6,
            "resident_bytes": runner.resident_bytes(),
            "peak_slab_bytes": runner.peak_slab_bytes,
            "touched_clients": len(store)}
    us = [out[f"K{K}"]["per_round_us"] for K in Ks]
    res = [out[f"K{K}"]["resident_bytes"] for K in Ks]
    out["flat_in_K"] = {"populations": list(Ks),
                        "wallclock_ratio": max(us) / min(us),
                        "resident_ratio": max(res) / min(res)}
    return out


def bench_weighted_era(fast: bool, prov: RunProvenance) -> dict:
    """einsum+softmax vs the fused weighted-ERA kernel on a (K, N, C) logit
    stack.  On CPU the kernel runs in interpret mode (recorded as such);
    the compiled comparison is meaningful on TPU/GPU.  ``comparable`` is
    sourced from the SAME `RunProvenance` stamped on the JSON header — the
    one ground truth for what the kernels actually ran as — so the flag
    can never disagree with the provenance a reader checks it against."""
    K, N, C = (8, 256, 64) if fast else (32, 2048, 256)
    key = jax.random.PRNGKey(0)
    p = jax.nn.softmax(jax.random.normal(key, (K, N, C)) * 2, -1)
    w = jnp.ones((K,)).at[0].set(0.0)

    einsum = jax.jit(lambda p, w: agg.weighted_era(p, w, 0.1))
    kernel = jax.jit(lambda p, w: agg.weighted_era(p, w, 0.1,
                                                   use_kernel=True))

    def timeit(fn, n=10):
        fn(p, w).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(n):
            out = fn(p, w)
        out.block_until_ready()
        return (time.perf_counter() - t0) / n * 1e6

    np.testing.assert_allclose(np.asarray(einsum(p, w)),
                               np.asarray(kernel(p, w)), atol=1e-5)
    return {"K": K, "N": N, "C": C, "backend": prov.backend,
            "kernel_interpret_mode": prov.kernel_interpret,
            # interpreted-kernel times are NOT an apples-to-apples
            # comparison with the einsum; only a provenance that positively
            # says "compiled" (False, not None/unknown) makes them one
            "comparable": prov.kernel_interpret is False,
            "einsum_us": timeit(einsum), "kernel_us": timeit(kernel)}


def bench_overlap(fast: bool) -> dict:
    """Serialized vs fused vs software-pipelined round schedules — the
    ISSUE-9 tentpole measurement, run on whatever device tier the ambient
    platform preset set up (CI: ``overlap-cpu8``, 8 fake CPU devices).

    Three schedules of the SAME rounds, asserted bitwise identical here,
    every run:

    * ``serialized``: the wire lands before compute starts — round_start
      dispatched and host-synced, then round_finish dispatched and synced.
      The honest "no overlap" baseline: two dispatches + two blocking
      syncs per round, the schedule a naive exchange-then-train loop runs.
    * ``fused``: today's ``overlap=False`` chunked scan (the pinned
      baseline) — one dispatch per chunk, XLA free to schedule within the
      fused round.
    * ``pipelined``: the ``overlap=True`` double-buffered scan — round
      r+1's exchange issued before round r's compute retires.

    Whether the latency-hiding scheduler actually split the exchange into
    async start/done pairs is read off the compiled HLO
    (`launch.platform.async_collectives_in`) and recorded next to the
    timings — on single-stream CPU backends the answer is False and the
    pipelined win is dispatch/sync overhead, which is exactly what the
    record says."""
    from repro.launch import platform as pf

    K, R, chunk, reps = (8, 12, 6, 3) if fast else (16, 32, 8, 5)
    task = build_image_task(seed=0, K=K, n_private=40 * K, n_open=80,
                            n_test=40, distribution="non_iid")
    hp = DSFLConfig(rounds=R, local_epochs=1, distill_epochs=1,
                    batch_size=20, open_batch=40, aggregation="era")
    algo = DSFLAlgorithm(apply_tiny_mlp, hp)
    eng = FedEngine(algo)          # shared: chunk cache holds both schedules
    n_open = task.open_x.shape[0]
    n_r = min(hp.open_batch, n_open)

    start_fn = jax.jit(algo.round_start)
    finish_fn = jax.jit(algo.round_finish)

    def serialized_run():
        """Exchange-then-train with a host sync at the wire boundary,
        on the engine's exact RNG discipline (same keys, same o_r)."""
        state = eng.init(init_tiny_mlp, task)
        rng = jax.random.PRNGKey(hp.seed)
        hist = []
        t0 = time.perf_counter()
        for r in range(R):
            rng, rk, ri = jax.random.split(rng, 3)
            o_idx = jax.random.choice(ri, n_open, (n_r,), replace=False)
            ctx = eng.make_ctx(task, o_idx=o_idx)
            inflight = start_fn(state, ctx, rk)
            jax.block_until_ready(inflight)            # the wire lands...
            state, m = finish_fn(state, ctx, inflight, rk)
            _block(state)                              # ...then compute
            hist.append({"round": r + 1,
                         **{k: float(v) for k, v in m.items()
                            if jnp.ndim(v) == 0}})
        return (time.perf_counter() - t0) / R * 1e6, hist, state

    def engine_run(overlap):
        state = eng.init(init_tiny_mlp, task)
        t0 = time.perf_counter()
        state = eng.run(state, task, rounds=R, chunk_rounds=chunk,
                        overlap=overlap)
        _block(state)
        return (time.perf_counter() - t0) / R * 1e6, list(eng.history), state

    legs = {"serialized": serialized_run,
            "fused": lambda: engine_run(False),
            "pipelined": lambda: engine_run(True)}
    # warmup all three (compiles), asserting the acceptance-criteria parity:
    # every schedule must be bitwise the same training run
    warm = {name: fn() for name, fn in legs.items()}
    ref_hist, ref_state = warm["fused"][1], warm["fused"][2]
    for name, (_, hist, state) in warm.items():
        assert hist == ref_hist, (
            f"{name} schedule history diverged from fused: "
            f"{hist[-1]} != {ref_hist[-1]}")
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(ref_state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # interleaved best-of-reps: alternating legs cancel cache-warmth drift
    times = {name: min(ts) for name, ts in zip(
        legs, zip(*[[legs[name]()[0] for name in legs]
                    for _ in range(reps)]))}

    # did the latency-hiding scheduler split the exchange? read the HLO
    state0 = eng.init(init_tiny_mlp, task)
    ctx0 = eng.make_ctx(task)
    fn = eng._get_chunk(chunk, n_open, n_r, state0, ctx0, None, overlap=True)
    hlo = fn.lower(state0, ctx0, jax.random.PRNGKey(hp.seed),
                   None).compile().as_text()
    preset = pf.active()
    return {"clients": K, "rounds": R, "chunk_rounds": chunk,
            "n_devices": jax.device_count(),
            "backend": jax.default_backend(),
            "platform_preset": preset.name if preset else None,
            "latency_hiding_fired": pf.async_collectives_in(hlo),
            "serialized_us": times["serialized"],
            "fused_us": times["fused"],
            "pipelined_us": times["pipelined"],
            "comm_hidden_us": times["serialized"] - times["pipelined"],
            "speedup_vs_serialized": (times["serialized"]
                                      / times["pipelined"]),
            "bitwise_identical": True}


def run(fast: bool = True):
    """benchmarks.run entry: (name, us_per_call, derived) rows +
    BENCH_engine.json side effect."""
    prov = RunProvenance.collect()
    scan = bench_loop_vs_scan(fast)
    part = bench_participation(fast)
    popu = bench_population_scaling(fast)
    wera = bench_weighted_era(fast, prov)
    over = bench_overlap(fast)
    with open(OUT_JSON, "w") as f:
        # provenance header: which commit/jax/backend produced these numbers
        json.dump({"provenance": prov.asdict(),
                   "scan": scan, "participation": part,
                   "population_scaling": popu,
                   "weighted_era": wera, "overlap": over}, f, indent=2)

    rows = []
    for chunk in CHUNKS:
        us = scan["per_round_us"][f"chunk{chunk}"]
        rows.append((f"engine_round_chunk{chunk}", us,
                     f"speedup={scan['speedup_vs_loop'][f'chunk{chunk}']:.2f}x"))
    for frac in FRACTIONS:
        rec = part[f"fraction{frac}"]
        rows.append((f"participation_sparse_f{frac}", rec["sparse_us"],
                     f"dense={rec['dense_us']:.0f}us "
                     f"speedup={rec['speedup']:.2f}x bitwise="
                     + ("ok" if rec["bitwise_identical"] else "ulp-drift")))
    for K in popu["flat_in_K"]["populations"]:
        rec = popu[f"K{K}"]
        rows.append((f"cohort_round_K{K}", rec["per_round_us"],
                     f"resident={rec['resident_bytes']}B "
                     f"slab={rec['peak_slab_bytes']}B "
                     f"touched={rec['touched_clients']}"))
    mode = "interpret" if wera["kernel_interpret_mode"] else "compiled"
    rows.append(("weighted_era_einsum", wera["einsum_us"], ""))
    rows.append(("weighted_era_kernel", wera["kernel_us"],
                 f"backend={wera['backend']} mode={mode}"
                 + ("" if wera["comparable"]
                    else " (interpreted: not comparable to einsum)")))
    for leg in ("serialized", "fused", "pipelined"):
        rows.append((f"overlap_{leg}", over[f"{leg}_us"],
                     f"devices={over['n_devices']} "
                     f"preset={over['platform_preset']} "
                     f"lhs_fired={over['latency_hiding_fired']} bitwise=ok"))
    return rows


def main(argv=None) -> int:
    from repro.launch import platform as pf

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI tier: tiny MLP, 8 clients, 32 rounds; asserts "
                         "the chunked scan beats the per-round loop")
    pf.add_args(ap)
    args = ap.parse_args(argv)
    # BEFORE any jax computation: the preset's XLA_FLAGS must be in the
    # environment when the backend lazily initializes
    pf.from_args(args)
    print("name,us_per_call,derived")
    for name, us, derived in run(fast=args.smoke):
        print(f"{name},{us:.1f},{derived}", flush=True)
    with open(OUT_JSON) as f:
        bench = json.load(f)
    per_round = bench["scan"]["per_round_us"]
    part = bench["participation"]
    print(f"wrote {OUT_JSON}: {per_round}")
    print(f"participation (K={part['clients']}): " + ", ".join(
        f"f={f} {part[f'fraction{f}']['speedup']:.2f}x" for f in FRACTIONS))
    popu = bench["population_scaling"]
    flat = popu["flat_in_K"]
    print(f"population scaling (m={popu['cohort']}): "
          + ", ".join(f"K={K} {popu[f'K{K}']['per_round_us']:.0f}us"
                      for K in flat["populations"])
          + f"  wallclock_ratio={flat['wallclock_ratio']:.2f} "
          f"resident_ratio={flat['resident_ratio']:.2f}")
    over = bench["overlap"]
    print(f"overlap (devices={over['n_devices']}, "
          f"preset={over['platform_preset']}, "
          f"lhs_fired={over['latency_hiding_fired']}): "
          f"serialized={over['serialized_us']:.0f}us "
          f"fused={over['fused_us']:.0f}us "
          f"pipelined={over['pipelined_us']:.0f}us "
          f"hidden={over['comm_hidden_us']:.0f}us/round")
    if args.smoke:
        assert per_round["chunk32"] < per_round["chunk1"], (
            "scan chunking failed to beat the per-round loop: "
            f"{per_round}")
        sp = part["fraction0.1"]["speedup"]
        assert sp >= 3.0, (
            f"participation-sparse round only {sp:.2f}x over dense masked "
            f"at 10% participation (expected >= 3x): {part}")
        # the tentpole headline: at fixed cohort size, a 100x larger fleet
        # costs neither wallclock nor resident client-state memory
        assert flat["wallclock_ratio"] <= 3.0, (
            f"cohort round wallclock not flat in K: {popu}")
        assert flat["resident_ratio"] <= 2.0, (
            f"resident client state not flat in K: {popu}")
        # ISSUE-9 acceptance: the pipelined schedule must beat the
        # host-synced serialized one (and not regress the fused baseline
        # beyond noise) on the multi-device CI tier
        assert over["pipelined_us"] < over["serialized_us"], (
            f"pipelined schedule slower than serialized: {over}")
        assert over["pipelined_us"] <= over["fused_us"] * 1.25, (
            f"pipelined schedule regressed the fused baseline: {over}")
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
