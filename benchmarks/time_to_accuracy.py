"""Accuracy vs *cumulative upload time* under a heterogeneous device fleet
(the paper's Figs. 5-8 x-axis, which a round-indexed history cannot give).

Runs DSFL-ERA / DSFL-SA vs FD vs FedAvg through `repro.sim.SimRunner`: a
lognormal-link `ClientPopulation`, uniform-K partial participation, and a
virtual clock charged from the *measured* `core.wire` codec bytes — so the
communication-time efficiency claim is checked on real encoded tensors, not
the analytic `CommModel` arithmetic (which stays as the cross-check: the
smoke mode asserts measured uplink bytes match it exactly, and that the
emitted wallclock/byte series are monotone).

  PYTHONPATH=src python -m benchmarks.time_to_accuracy --smoke   # CI tier
  PYTHONPATH=src python -m benchmarks.time_to_accuracy           # fuller run

Also registered in benchmarks.run as the ``ttacc`` key.
"""
from __future__ import annotations

import argparse
import sys
import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.core.algorithms import (DSFLAlgorithm, FDAlgorithm, FDConfig,
                                   FedAvgAlgorithm, FedAvgConfig)
from repro.core.comm import CommModel, fmt_bytes
from repro.core.engine import FedEngine, make_eval_fn
from repro.core.protocol import DSFLConfig
from repro.data.pipeline import build_image_task
from repro.models.base import param_count
from repro.models.smallnets import apply_tiny_mlp, init_tiny_mlp
from repro.sim import ClientPopulation, SimRunner, SyncScheduler


@dataclass
class SimCfg:
    K: int = 8
    rounds: int = 3
    local_epochs: int = 1
    distill_epochs: int = 1
    batch_size: int = 20
    open_batch: int = 80
    n_private: int = 320
    n_open: int = 80
    n_test: int = 160
    lr: float = 0.1
    fraction: float = 0.5          # partial participation
    deadline: float | None = None
    seed: int = 0


METHODS = ("dsfl_era", "dsfl_sa", "fd", "fedavg")


def build_engine(method: str, task, sc: SimCfg) -> FedEngine:
    ev = make_eval_fn(apply_tiny_mlp, task.x_test, task.y_test)
    if method.startswith("dsfl"):
        hp = DSFLConfig(rounds=sc.rounds, local_epochs=sc.local_epochs,
                        distill_epochs=sc.distill_epochs,
                        batch_size=sc.batch_size, open_batch=sc.open_batch,
                        lr=sc.lr, lr_distill=sc.lr,
                        aggregation=method.split("_")[1], seed=sc.seed)
        return FedEngine(DSFLAlgorithm(apply_tiny_mlp, hp), ev)
    if method == "fd":
        hp = FDConfig(rounds=sc.rounds, local_epochs=sc.local_epochs,
                      batch_size=sc.batch_size, lr=sc.lr, gamma=0.1,
                      n_classes=task.n_classes, seed=sc.seed)
        return FedEngine(FDAlgorithm(apply_tiny_mlp, hp), ev)
    if method == "fedavg":
        hp = FedAvgConfig(rounds=sc.rounds, local_epochs=sc.local_epochs,
                          batch_size=sc.batch_size, lr=sc.lr, seed=sc.seed)
        return FedEngine(FedAvgAlgorithm(apply_tiny_mlp, hp), ev)
    raise ValueError(method)


def simulate(method: str, task, sc: SimCfg,
             pop: ClientPopulation) -> SimRunner:
    eng = build_engine(method, task, sc)
    runner = SimRunner(eng, SyncScheduler(pop, fraction=sc.fraction,
                                          deadline=sc.deadline), seed=sc.seed)
    state = eng.init(lambda k: init_tiny_mlp(k), task)
    runner.run(state, task, rounds=sc.rounds)
    return runner


def _assert_series(runner: SimRunner, method: str) -> None:
    t = np.asarray(runner.history.series("t_cum"))
    b = np.asarray(runner.history.series("cum_bytes"))
    assert np.all(np.diff(t) > 0), f"{method}: wallclock not monotone: {t}"
    assert np.all(np.diff(b) > 0), f"{method}: cum bytes not monotone: {b}"


def run(fast: bool = True):
    """benchmarks.run entry: returns (name, us_per_call, derived) rows."""
    sc = SimCfg() if fast else SimCfg(K=20, rounds=10, n_private=2000,
                                      n_open=500, open_batch=500)
    task = build_image_task(seed=sc.seed, K=sc.K, n_private=sc.n_private,
                            n_open=sc.n_open, n_test=sc.n_test,
                            distribution="non_iid")
    pop = ClientPopulation.lognormal(sc.seed, sc.K, uplink_median=1e5,
                                     uplink_sigma=1.0)
    w, s = init_tiny_mlp(jax.random.PRNGKey(0))
    cm = CommModel(sc.K, task.n_classes, param_count(w) + param_count(s),
                   min(sc.open_batch, sc.n_open))
    rows, runners = [], {}
    for method in METHODS:
        t0 = time.perf_counter()
        runner = simulate(method, task, sc, pop)
        us = (time.perf_counter() - t0) / sc.rounds * 1e6
        runners[method] = runner
        _assert_series(runner, method)
        last = runner.history[-1]
        rows.append((f"ttacc_{method}", us,
                     f"acc={last['test_acc']:.3f}@vt={last['t_cum']:.0f}s"
                     f"/{fmt_bytes(last['cum_bytes'])}"))

    # measured-vs-analytic cross-check: DSFL's per-client uplink beats
    # FedAvg's by exactly the CommModel Table-1 ratio
    up_dsfl, _ = runners["dsfl_era"].engine.measured_leg_bytes(
        runners["dsfl_era"].engine.algo.init(
            jax.random.PRNGKey(0), lambda k: init_tiny_mlp(k), task), task)
    up_fa, _ = runners["fedavg"].engine.measured_leg_bytes(
        runners["fedavg"].engine.algo.init(
            jax.random.PRNGKey(0), lambda k: init_tiny_mlp(k), task), task)
    assert up_dsfl * (sc.K + 1) == cm.dsfl_round(), "DSFL measured != analytic"
    assert up_fa * (sc.K + 1) == cm.fl_round(), "FedAvg measured != analytic"
    assert up_dsfl < up_fa, "DSFL uplink should be below FedAvg's"
    rows.append(("ttacc_uplink_ratio", 0.0,
                 f"fedavg/dsfl={up_fa / up_dsfl:.1f}x(=CommModel ratio "
                 f"{cm.fl_round() / cm.dsfl_round():.1f}x)"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI tier: tiny MLP, 8 clients, 3 rounds")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for name, us, derived in run(fast=args.smoke):
        print(f"{name},{us:.1f},{derived}", flush=True)
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
