"""Shared experiment runner for the paper-table benchmarks.

Runs the four methods of the paper on the synthetic federated image task,
all through the single algorithm-agnostic `FedEngine`:
  dsfl_era / dsfl_sa  - Algorithm 1 with ERA / SA aggregation
  fl                  - Benchmark 1 (FedAvg)
  fd                  - Benchmark 2 (federated distillation)
  single              - one client trains alone (lower bound)
Histories carry per-round test accuracy + cumulative communication bytes
*measured* on the actually-encoded wire payload (`repro.core.wire`), not
just computed analytically — `CommModel` stays as the cross-check.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.algorithms import (DSFLAlgorithm, FDAlgorithm, FDConfig,
                                   FedAvgAlgorithm, FedAvgConfig)
from repro.core.client import LocalSpec, local_update
from repro.core.comm import CommModel
from repro.core.engine import FedEngine, make_eval_fn
from repro.core.protocol import DSFLConfig
from repro.data.pipeline import FederatedImageTask, build_image_task
from repro.models.base import param_count
from repro.models.smallnets import apply_mnist_cnn, init_mnist_cnn
from repro.optim import optimizers as opt_lib


def cnn_init(k):
    return init_mnist_cnn(k, image_hw=16, widths=(8, 16), fc=32)


APPLY = apply_mnist_cnn


@dataclass
class ExpConfig:
    K: int = 10
    rounds: int = 15
    local_epochs: int = 2
    distill_epochs: int = 2
    batch_size: int = 50
    open_batch: int = 500
    lr: float = 0.1
    temperature: float = 0.1
    gamma: float = 0.1           # FD distill regularizer weight
    seed: int = 0


def make_clients(key, K):
    wk = jax.vmap(lambda k: cnn_init(k)[0])(jax.random.split(key, K))
    sk = jax.vmap(lambda k: cnn_init(k)[1])(jax.random.split(key, K))
    return wk, sk


def comm_model(task: FederatedImageTask, ec: ExpConfig) -> CommModel:
    w, s = cnn_init(jax.random.PRNGKey(0))
    return CommModel(ec.K, task.n_classes, param_count(w) + param_count(s),
                     min(ec.open_batch, task.open_x.shape[0]))


def dsfl_engine(task, ec: ExpConfig, aggregation="era", corrupt=None,
                temperature=None):
    hp = DSFLConfig(rounds=ec.rounds, local_epochs=ec.local_epochs,
                    distill_epochs=ec.distill_epochs, batch_size=ec.batch_size,
                    open_batch=min(ec.open_batch, task.open_x.shape[0]),
                    lr=ec.lr, lr_distill=ec.lr,
                    aggregation=aggregation,
                    temperature=ec.temperature if temperature is None
                    else temperature, seed=ec.seed)
    algo = DSFLAlgorithm(APPLY, hp, corrupt=corrupt)
    return FedEngine(algo, make_eval_fn(APPLY, task.x_test, task.y_test))


def run_dsfl(task, ec: ExpConfig, aggregation="era", corrupt=None,
             temperature=None, return_state=False):
    key = jax.random.PRNGKey(ec.seed)
    wg, sg = cnn_init(key)
    wk, sk = make_clients(key, ec.K)
    eng = dsfl_engine(task, ec, aggregation, corrupt, temperature)
    state = eng.algo.init_from(wk, sk, wg, sg)
    state = eng.run(state, task)
    per_round = eng.measured_round_bytes(state, task)
    one_off = comm_model(task, ec).open_set_distribution(
        task.open_x.shape[0], task.open_x[0].size)
    for h in eng.history:
        h["cum_bytes"] = h["round"] * per_round + one_off
    if return_state:
        return eng.history, state
    return eng.history


def run_fl(task, ec: ExpConfig, poison_fn=None):
    key = jax.random.PRNGKey(ec.seed)
    w0, s0 = cnn_init(key)
    algo = FedAvgAlgorithm(APPLY, FedAvgConfig(
        rounds=ec.rounds, local_epochs=ec.local_epochs,
        batch_size=ec.batch_size, lr=ec.lr, seed=ec.seed))

    def on_round(r, state):
        if poison_fn is None:
            return state
        w, s = poison_fn(r, state.server.params, state.server.model_state)
        return dataclasses.replace(state, server=dataclasses.replace(
            state.server, params=w, model_state=s))

    eng = FedEngine(algo, make_eval_fn(APPLY, task.x_test, task.y_test),
                    on_round=on_round)
    state = algo.init_from(w0, s0)
    state = eng.run(state, task, weights=jnp.ones((ec.K,)))
    per_round = eng.measured_round_bytes(state, task)
    for h in eng.history:
        h["cum_bytes"] = h["round"] * per_round
    return eng.history, (state.server.params, state.server.model_state)


def run_fd(task, ec: ExpConfig):
    key = jax.random.PRNGKey(ec.seed)
    wk, sk = make_clients(key, ec.K)
    algo = FDAlgorithm(APPLY, FDConfig(
        rounds=ec.rounds, local_epochs=ec.local_epochs,
        batch_size=ec.batch_size, lr=ec.lr, gamma=ec.gamma,
        n_classes=task.n_classes, seed=ec.seed))
    eng = FedEngine(algo, make_eval_fn(APPLY, task.x_test, task.y_test))
    state = algo.init_from(wk, sk)
    state = eng.run(state, task)
    per_round = eng.measured_round_bytes(state, task)
    for h in eng.history:
        h["cum_bytes"] = h["round"] * per_round
    return eng.history, eng.last_metrics["global_logit"]


def run_single(task, ec: ExpConfig):
    """One client trains alone on its shard (paper's 'Single Client' row)."""
    key = jax.random.PRNGKey(ec.seed)
    w, s = cnn_init(key)
    opt = opt_lib.make("sgd", ec.lr)
    spec = LocalSpec(APPLY, opt, ec.local_epochs, ec.batch_size)
    o = opt.init(w)
    eval_fn = make_eval_fn(APPLY, task.x_test, task.y_test)
    history = []
    upd = jax.jit(lambda w, s, o, rk: local_update(
        spec, w, s, o, task.x_clients[0], task.y_clients[0], rk))
    rng = key
    for r in range(ec.rounds):
        rng, rk = jax.random.split(rng)
        w, s, o, _ = upd(w, s, o, rk)
        history.append({"round": r + 1, **eval_fn(w, s), "cum_bytes": 0})
    return history


def top_acc(history):
    return max(h["test_acc"] for h in history)


def comu_at(history, acc: float):
    """Cumulative bytes to first reach `acc` (None if never)."""
    for h in history:
        if h["test_acc"] >= acc:
            return h["cum_bytes"]
    return None


def timed(fn, *args, n=3, **kw):
    fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6, out
