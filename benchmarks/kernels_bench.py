"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp reference — the
correctness/latency harness for the three TPU kernels.  On CPU interpret mode
is (much) slower than XLA; the numbers validate plumbing, not TPU speed."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from .common import timed


def run(fast: bool = True):
    key = jax.random.PRNGKey(0)
    rows = []
    # ERA
    p = jax.nn.softmax(jax.random.normal(key, (10, 256, 46)), -1)
    us_k, _ = timed(lambda x: ops.era_sharpen(x, 0.1), p, n=2)
    us_r, _ = timed(jax.jit(lambda x: ref.era_sharpen_ref(x, 0.1)), p)
    rows.append(("kernel/era_sharpen", us_k, f"ref_us={us_r:.0f} allclose=1"))
    # distill loss fwd+grad
    z = jax.random.normal(key, (512, 2048)) * 3
    t = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1),
                                         (512, 2048)), -1)
    us_k, _ = timed(lambda a: ops.distill_loss(a, t), z, n=2)
    us_r, _ = timed(jax.jit(lambda a: jnp.mean(ref.distill_loss_ref(a, t))), z)
    rows.append(("kernel/distill_loss", us_k, f"ref_us={us_r:.0f}"))
    # ssd chunk
    M, Q, H, P, G, N = 8, 64, 8, 32, 1, 32
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (1, M, Q, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, M, Q, H)))
    dA = -dt * 0.3
    B = jax.random.normal(ks[2], (1, M, Q, G, N))
    C = jax.random.normal(ks[3], (1, M, Q, G, N))
    us_k, _ = timed(lambda *a: ops.ssd_chunk(*a, H // G), x, dt, dA, B, C, n=2)
    rows.append(("kernel/ssd_chunk", us_k, f"tiles={M * H}"))
    return rows
