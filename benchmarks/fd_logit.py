"""Paper Fig. 2: FD's global per-class logit collapses to near-one-hot under
strong non-IID (the failure DS-FL fixes).  We measure the one-hotness
(max-probability) of the FD global logit per data distribution."""
from __future__ import annotations

import jax.numpy as jnp

from repro.data.pipeline import build_image_task
from .common import ExpConfig, run_fd


def run(fast: bool = True):
    ec = ExpConfig(K=4 if fast else 10, rounds=2 if fast else 8)
    rows = []
    for dist, label in [("iid", "iid"), ("dirichlet:1.0", "weak_non_iid"),
                        ("non_iid", "strong_non_iid")]:
        task = build_image_task(seed=0, K=ec.K, n_private=800, n_open=200,
                                n_test=200, distribution=dist)
        _, tg = run_fd(task, ec)
        onehotness = float(jnp.mean(jnp.max(tg, axis=-1)))
        entropy = float(jnp.mean(
            -jnp.sum(tg * jnp.log(jnp.clip(tg, 1e-9, 1)), -1)))
        rows.append((f"fig2/fd_global_logit_{label}", 0.0,
                     f"max_prob={onehotness:.3f} entropy={entropy:.3f}"))
    return rows
