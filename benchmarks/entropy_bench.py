"""Paper Fig. 3 (SA global-logit entropy IID vs non-IID) and Fig. 9
(entropy under noisy data) — entropy traces of the aggregated teacher."""
from __future__ import annotations

from repro.data.pipeline import build_image_task
from .common import ExpConfig, run_dsfl


def run(fast: bool = True):
    ec = ExpConfig(K=4 if fast else 10, rounds=3 if fast else 10,
                   open_batch=200)
    rows = []
    for dist in ("iid", "non_iid"):
        task = build_image_task(seed=0, K=ec.K, n_private=800, n_open=400,
                                n_test=400, distribution=dist)
        hist = run_dsfl(task, ec, "sa")
        rows.append((f"fig3/sa_entropy_{dist}", 0.0,
                     f"first={hist[0]['sa_entropy']:.3f} "
                     f"last={hist[-1]['sa_entropy']:.3f}"))
    # Fig. 9a: noisy open data raises SA entropy; ERA suppresses it
    task_noisy = build_image_task(seed=0, K=ec.K, n_private=800, n_open=400,
                                  n_test=400, distribution="non_iid",
                                  noisy_open=400)
    for aggname in ("sa", "era"):
        hist = run_dsfl(task_noisy, ec, aggname)
        rows.append((f"fig9/{aggname}_entropy_noisy_open", 0.0,
                     f"teacher_entropy_last={hist[-1]['global_entropy']:.3f}"))
    return rows
