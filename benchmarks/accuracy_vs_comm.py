"""Paper Fig. 5 + Table 3: test accuracy vs cumulative communication cost for
DS-FL(ERA) / DS-FL(SA) / FL / FD / single-client under strong non-IID."""
from __future__ import annotations

import json
import os
import time

from repro.data.pipeline import build_image_task
from .common import (ExpConfig, comu_at, run_dsfl, run_fd, run_fl,
                     run_single, top_acc)


def run(fast: bool = True, save: str | None = "experiments/fig5.json"):
    ec = ExpConfig(K=4 if fast else 10, rounds=4 if fast else 20,
                   open_batch=200 if fast else 500)
    task = build_image_task(seed=0, K=ec.K,
                            n_private=800 if fast else 4000,
                            n_open=400 if fast else 2000,
                            n_test=400 if fast else 1000,
                            distribution="non_iid")
    rows, all_hist = [], {}
    for name, runner in [
        ("dsfl_era", lambda: run_dsfl(task, ec, "era")),
        ("dsfl_sa", lambda: run_dsfl(task, ec, "sa")),
        ("fl", lambda: run_fl(task, ec)[0]),
        ("fd", lambda: run_fd(task, ec)[0]),
        ("single", lambda: run_single(task, ec)),
    ]:
        t0 = time.time()
        hist = runner()
        dt = (time.time() - t0) / ec.rounds * 1e6
        all_hist[name] = hist
        ta = top_acc(hist)
        thresh = 0.45 if fast else 0.6
        cu = comu_at(hist, thresh)
        rows.append((f"fig5/{name}", dt,
                     f"top_acc={ta:.3f} comu@{thresh:.0%}="
                     f"{'-' if cu is None else f'{cu:.2e}'}"))
    if save:
        os.makedirs(os.path.dirname(save), exist_ok=True)
        with open(save, "w") as f:
            json.dump({"config": ec.__dict__, "histories": all_hist}, f,
                      indent=1, default=float)
    return rows
