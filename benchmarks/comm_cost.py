"""Paper Tables 1-2: communication cost per round, per method, per model —
analytic accounting with the paper's exact architectures, plus measured
aggregation-op latency (us_per_call) at those payload sizes."""
from __future__ import annotations

import jax

from repro.core.aggregation import era, sa
from repro.core.comm import CommModel
from .common import timed

PAPER_SETUPS = [
    # name, K, classes, params, paper FL/FD/DSFL bytes
    ("mnist_cnn", 100, 10, 583_242, (236.1e6, 40.4e3, 4.0e6)),
    ("fmnist_cnn", 100, 10, 2_760_228, (1.1e9, 40.4e3, 4.0e6)),
    ("imdb_lstm", 10, 2, 646_338, (28.6e6, 176.0, 88e3)),
    ("reuters_dnn", 10, 46, 5_194_670, (228.8e6, 93e3, 2.0e6)),
]


def run(fast: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)
    for name, K, C, P, (fl_p, fd_p, ds_p) in PAPER_SETUPS:
        cm = CommModel(K, C, P, 1000)
        # measured ERA latency at the actual upload size (K x |o_r| x C)
        probs = jax.nn.softmax(
            jax.random.normal(key, (min(K, 10), 1000, C)), -1)
        us_era, _ = timed(jax.jit(lambda p: era(p, 0.1)), probs)
        for method, ours, paper in [("fl", cm.fl_round(), fl_p),
                                    ("fd", cm.fd_round(), fd_p),
                                    ("dsfl", cm.dsfl_round(), ds_p)]:
            rel = abs(ours - paper) / paper
            rows.append((f"comm/{name}/{method}", us_era if method == "dsfl"
                         else 0.0,
                         f"bytes={ours:.3e} paper={paper:.3e} err={rel:.3f}"))
        rows.append((f"comm/{name}/dsfl_topk32", 0.0,
                     f"bytes={cm.dsfl_topk_round(32):.3e} (beyond-paper)"))
    return rows
