"""Paper Fig. 6: effect of the ERA temperature T on convergence speed and
teacher entropy (T=0.5 slower than SA; T=0.1/0.01 faster)."""
from __future__ import annotations

from repro.data.pipeline import build_image_task
from .common import ExpConfig, run_dsfl, top_acc


def run(fast: bool = True):
    ec = ExpConfig(K=4 if fast else 10, rounds=3 if fast else 12,
                   open_batch=200)
    task = build_image_task(seed=0, K=ec.K, n_private=800, n_open=400,
                            n_test=400, distribution="non_iid")
    rows = []
    hist = run_dsfl(task, ec, "sa")
    rows.append(("fig6/sa", 0.0,
                 f"top_acc={top_acc(hist):.3f} "
                 f"entropy={hist[-1]['global_entropy']:.3f}"))
    for T in (0.01, 0.1, 0.5):
        hist = run_dsfl(task, ec, "era", temperature=T)
        rows.append((f"fig6/era_T{T}", 0.0,
                     f"top_acc={top_acc(hist):.3f} "
                     f"entropy={hist[-1]['global_entropy']:.3f}"))
    return rows
