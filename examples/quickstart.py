"""DS-FL quickstart on the unified `FedAlgorithm` API: 10 clients with
non-IID private digit data collaborate by exchanging logits on a shared
unlabeled open set (never parameters).

The same three lines run any algorithm in the repo:

    algo  = DSFLAlgorithm(apply_fn, hp)          # or FDAlgorithm / FedAvg...
    eng   = FedEngine(algo, make_eval_fn(...))
    state = eng.run(eng.init(model_init, task), task)

``eng.run(..., chunk_rounds=k)`` compiles k rounds into one `lax.scan` —
one jit dispatch and one host sync per chunk instead of per round, bitwise
identical to the default loop (``--chunk-rounds`` below; with eval the
chunk snaps to ``log_every`` so every logged round still gets scored).

  PYTHONPATH=src python examples/quickstart.py          # ~2 min on CPU
  PYTHONPATH=src python examples/quickstart.py --fast   # smoke (~40 s)

On TPU/GPU the server's "4. Aggregation" can run through the fused Pallas
mean+sharpen kernel: ``aggregation.era(probs, T, use_kernel=True)`` (or
``aggregate(..., use_kernel=True)``).  Its ``interpret`` flag defaults to
auto — interpret mode on CPU (this container), the compiled kernel on real
hardware — so the same call works in both places; any open-batch size is
fine (the kernel pads its row blocks internally).
"""
import argparse
import sys

import jax

from repro.core.algorithms import DSFLAlgorithm
from repro.core.comm import CommModel, fmt_bytes
from repro.core.engine import FedEngine, make_eval_fn
from repro.core.protocol import DSFLConfig
from repro.core.wire import TopKCodec
from repro.data.pipeline import build_image_task
from repro.models.base import param_count
from repro.models.smallnets import apply_mnist_cnn, init_mnist_cnn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--aggregation", default="era",
                    choices=["era", "sa", "weighted_era"])
    ap.add_argument("--chunk-rounds", type=int, default=1,
                    help="rounds fused per compiled lax.scan chunk "
                         "(bitwise identical to the per-round loop)")
    args = ap.parse_args(argv)

    K = 4 if args.fast else args.clients
    rounds = 3 if args.fast else args.rounds
    task = build_image_task(seed=0, K=K, n_private=(640 if args.fast else 3000),
                            n_open=(320 if args.fast else 1500),
                            n_test=(320 if args.fast else 1000),
                            distribution="non_iid")

    def init(k):
        return init_mnist_cnn(k, image_hw=16, widths=(8, 16), fc=32)

    hp = DSFLConfig(rounds=rounds, local_epochs=2, distill_epochs=2,
                    batch_size=40, open_batch=min(320, task.open_x.shape[0]),
                    aggregation=args.aggregation)
    algo = DSFLAlgorithm(apply_mnist_cnn, hp)
    eng = FedEngine(algo, make_eval_fn(apply_mnist_cnn, task.x_test,
                                       task.y_test))
    state = eng.init(init, task)
    # eval forces a host sync per logged round, so the log cadence rides the
    # chunk: log_every == chunk keeps each scan segment fully fused (with
    # the default --chunk-rounds 1 this is exactly the old per-round loop)
    chunk = max(1, min(args.chunk_rounds, rounds))
    state = eng.run(state, task, chunk_rounds=chunk, log_every=chunk)

    wg, sg = algo.eval_params(state)
    n_params = param_count(wg) + param_count(sg)
    cm = CommModel(K, task.n_classes, n_params, hp.open_batch)
    dsfl_bytes = eng.measured_round_bytes(state, task)   # measured on the wire
    topk_bytes = FedEngine(algo, codec=TopKCodec(k=3, n_classes=task.n_classes)
                           ).measured_round_bytes(state, task)
    print(f"\nmodel: {n_params:,} params | {K} clients | "
          f"aggregation={hp.aggregation}")
    print(f"per-round comm  FL(FedAvg): {fmt_bytes(cm.fl_round())}   "
          f"DS-FL: {fmt_bytes(dsfl_bytes)}  "
          f"({cm.fl_round() / dsfl_bytes:.0f}x reduction; "
          f"top-3 codec: {fmt_bytes(topk_bytes)})")
    assert dsfl_bytes == cm.dsfl_round(), "measured != analytic comm"
    for h in eng.history:
        print(f"round {h['round']:3d}  server acc {h['test_acc']:.3f}  "
              f"teacher entropy {h['global_entropy']:.3f}")
    ok = eng.history[-1]["test_acc"] > (0.25 if args.fast else 0.5)
    print("OK" if ok else "UNDERTRAINED")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
