"""The paper's headline claim at datacenter scale: compare the CROSS-POD
collective bytes of one DS-FL round vs one FedAvg round on the 2x16x16
production mesh (2 pods = 2 federated clients).

Both rounds are the unified `FedAlgorithm` implementations
(`core.llm_algorithms`) — the same ``round``/``shardings`` surface
`FedEngine` jits — lowered here with explicit in_shardings so the
collectives can be read straight from the compiled HLO.  DS-FL's only
cross-pod traffic is the open-batch logit exchange; FedAvg all-reduces
every parameter.

Needs the 512-device dry-run environment:
  PYTHONPATH=src python examples/multi_pod_comm.py --arch qwen1.5-4b
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.algorithms import BatchCtx, ClientState, RoundState
from repro.core.llm_algorithms import (LLMDSFLAlgorithm, LLMFedAvgAlgorithm,
                                       LLMFedAvgHP)
from repro.core.llm_dsfl import LLMDsflHP
from repro.core.comm import fmt_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import collective_bytes, cross_pod_bytes
from repro.launch.specs import input_specs
from repro.models.shardctx import axis_ctx
from repro.configs.shapes import InputShape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--topk", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=True)
    shape = InputShape("custom", args.seq, args.batch, "train")
    spec = input_specs(cfg, shape, n_clients=2, topk=args.topk)
    ecfg = spec["cfg"]
    n_open = jax.tree.leaves(spec["open"])[0].shape[0]
    o_idx = jax.ShapeDtypeStruct((n_open,), jnp.int32)
    key = jax.random.PRNGKey(0)

    cases = [
        ("dsfl_round", LLMDSFLAlgorithm(ecfg, LLMDsflHP(topk=args.topk)),
         BatchCtx(x=spec["private"], open_x=spec["open"], o_idx=o_idx)),
        ("fedavg_round", LLMFedAvgAlgorithm(ecfg, LLMFedAvgHP(lr=1e-4)),
         BatchCtx(x=spec["private"])),
    ]
    results = {}
    for name, algo, ctx in cases:
        state = RoundState(clients=ClientState(params=spec["params"]))
        st_sh, ctx_sh = algo.shardings(mesh, state, ctx)
        jitted = jax.jit(algo.round, in_shardings=(st_sh, ctx_sh, None))
        with axis_ctx(mesh, batch_axes=("data",)):
            compiled = jitted.lower(state, ctx, key).compile()
        txt = compiled.as_text()
        coll = cross_pod_bytes(txt)
        total = collective_bytes(txt)
        results[name] = coll
        print(f"{name:14s} CROSS-POD bytes/device: "
              f"{fmt_bytes(sum(coll.values()))}  "
              f"(all collectives: {fmt_bytes(sum(total.values()))})  "
              f"breakdown: { {k: fmt_bytes(v) for k, v in coll.items()} }",
              flush=True)
    d = sum(results["dsfl_round"].values())
    f = sum(results["fedavg_round"].values())
    if d:
        print(f"\nDS-FL round moves {f / d:.1f}x fewer collective bytes "
              f"than FedAvg on this mesh" if f > d else
              f"\nNOTE: model small / open batch large — DS-FL={fmt_bytes(d)}"
              f" vs FedAvg={fmt_bytes(f)} (the paper's advantage holds when"
              f" params >> open-batch logits; try --topk 32)")


if __name__ == "__main__":
    main()
