"""End-to-end driver: DS-FL across 2 simulated pods training a ~100M-param
decoder LM on synthetic domain-skewed token streams.

Full size (~100M params, a few hundred rounds) is a TPU job; on this CPU
container run with --smoke.  Either way this is the same code path the
multi-pod dry-run lowers (core.llm_dsfl.dsfl_round_step).

  PYTHONPATH=src python examples/train_dsfl_lm.py --smoke --steps 30
"""
import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--clients", type=int, default=2)
    args = ap.parse_args()

    argv = ["--arch", "qwen1.5-4b", "--mode", "dsfl",
            "--clients", str(args.clients), "--steps", str(args.steps)]
    if args.smoke:
        argv += ["--smoke", "--batch", "4", "--seq", "64", "--lr", "3e-3"]
    else:
        # ~100M-class config is selected by the launcher when not smoke;
        # on real hardware pass a production --arch instead.
        argv += ["--batch", "8", "--seq", "512", "--lr", "1e-3"]
    train_mod.main(argv)


if __name__ == "__main__":
    main()
