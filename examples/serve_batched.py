"""Batched-request serving demo, now on the `repro.serve` subsystem:
prompts are admitted through an `AdmissionQueue` into a slot-based
continuous-batching `ServeEngine` (ring-buffer KV caches for dense,
O(1) SSM state for mamba) — see ROADMAP.md "Serving" for the API and
`repro.launch.serve --lockstep` for the old whole-batch baseline.

``--decode-chunk d`` fuses d decode steps into one compiled scan (one
host sync per chunk) and ``--batch-insert`` admits the whole same-bucket
prompt group through one compiled batched prefill — both paths are
token-identical to the step-at-a-time defaults.

  PYTHONPATH=src python examples/serve_batched.py --arch mamba2-2.7b \
      --decode-chunk 8 --batch-insert
"""
import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--decode-chunk", type=int, default=1,
                    help="fused decode steps per dispatch (1 = per-token)")
    ap.add_argument("--batch-insert", action="store_true",
                    help="one compiled prefill shot per same-bucket group")
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--smoke",
            "--batch", str(args.batch), "--prompt-len", "32",
            "--gen", str(args.gen),
            "--decode-chunk", str(args.decode_chunk)]
    if args.batch_insert:
        argv.append("--batch-insert")
    serve_mod.main(argv)


if __name__ == "__main__":
    main()
