"""Batched-request serving demo, now on the `repro.serve` subsystem:
prompts are admitted through an `AdmissionQueue` into a slot-based
continuous-batching `ServeEngine` (ring-buffer KV caches for dense,
O(1) SSM state for mamba) — see ROADMAP.md "Serving" for the API and
`repro.launch.serve --lockstep` for the old whole-batch baseline.

  PYTHONPATH=src python examples/serve_batched.py --arch mamba2-2.7b
"""
import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve_mod.main(["--arch", args.arch, "--smoke",
                    "--batch", str(args.batch), "--prompt-len", "32",
                    "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
