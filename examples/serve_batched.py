"""Batched-request serving demo: prefill a batch of prompts, then greedy
decode with ring-buffer KV caches (dense) or O(1) SSM state (mamba).

  PYTHONPATH=src python examples/serve_batched.py --arch mamba2-2.7b
"""
import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve_mod.main(["--arch", args.arch, "--smoke",
                    "--batch", str(args.batch), "--prompt-len", "32",
                    "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
