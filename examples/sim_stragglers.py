"""DS-FL on a simulated mobile fleet — from 100 devices to a million.

Small fleets run the dense `SimRunner` path: 10% participation per round,
lognormal link rates, a straggler deadline — accuracy plotted against
*virtual wallclock* and measured cumulative bytes (the paper's Figs. 5-8
axes), all through the unchanged `FedEngine` round:

    pop    = ClientPopulation.lognormal(seed, K=100)
    sched  = SyncScheduler(pop, fraction=0.1, deadline=20.0, straggler="admit")
    eng    = FedEngine(algo, eval_fn)
    runner = SimRunner(eng, sched)
    state  = runner.run(eng.init(init, task), task, chunk_rounds=4)

``--chunk`` drives the *fused* sim path: sync participation is planned a
whole chunk ahead, and the chunk runs as one compiled `lax.scan` inside the
engine (`FedEngine.run(chunk_rounds=k, ctx_plan=...)`) — bitwise identical
to the per-round loop, without its one-dispatch-per-round host overhead.
At 10% participation the round is also *participation-sparse* by default
(``active_budget="auto"``): the engine computes only the scheduler's
budgeted ~``2 * ceil(0.1 * K)`` client lanes instead of the full K-client
stack — same bits, ~K/m cheaper.  ``--dense`` forces the full-stack masked
round for comparison.

Large fleets (K >= 10000, or ``--cohort``) switch to the **cohort-resident**
path, where nothing is O(K) per round: the scheduler draws m-client
cohorts as id arrays (O(m log K) — Floyd / cached-CDF draws), client state
lives host-side in a `ClientStore` keyed by global id (lazily initialized,
so untouched clients cost nothing), private data comes from a per-id
`SyntheticProvider`, and the engine runs its ordinary fused rounds over an
(S,)-lane slab.  At small K this path is bitwise identical to the dense
masked rounds (tests/test_cohort.py).  The headline configuration —

  PYTHONPATH=src python examples/sim_stragglers.py --clients 1000000 \\
      --fraction 1e-4                                  # ~1 min on CPU

— simulates a million-client federation at 0.01% participation: 100
clients train per round, the resident client state is ~100 rows per round
of history (printed alongside the wire bytes below), and per-round
wallclock is flat in K (benchmarks/engine_bench.py population_scaling).

  PYTHONPATH=src python examples/sim_stragglers.py          # ~2 min on CPU
  PYTHONPATH=src python examples/sim_stragglers.py --fast   # smoke (~30 s)
"""
import argparse
import sys

import jax

from repro.core.algorithms import DSFLAlgorithm
from repro.core.cohort import ClientStore
from repro.core.comm import fmt_bytes
from repro.core.engine import FedEngine, make_eval_fn
from repro.core.protocol import DSFLConfig
from repro.data.pipeline import (SyntheticProvider, build_image_task)
from repro.models.smallnets import apply_tiny_mlp, init_tiny_mlp
from repro.obs import cli as obs_cli
from repro.sim import (ClientPopulation, CohortRunner, SimRunner,
                       SyncScheduler)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--clients", type=int, default=100,
                    help="fleet size K (a million works: see --cohort)")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--participation", type=float, default=0.1)
    ap.add_argument("--fraction", type=float, default=None,
                    help="participation fraction per round (the paper's "
                         "C; alias of --participation, wins if both given)")
    ap.add_argument("--deadline", type=float, default=20.0)
    ap.add_argument("--chunk", type=int, default=4,
                    help="rounds fused per compiled lax.scan chunk "
                         "(1 = the per-round loop; bitwise identical)")
    ap.add_argument("--dense", action="store_true",
                    help="force the dense masked round (compute all K "
                         "clients) instead of the participation-sparse "
                         "plane; bitwise identical, ~K/m slower")
    ap.add_argument("--cohort", action="store_true",
                    help="force the cohort-resident path (automatic for "
                         "K >= 10000): O(m log K) scheduling, host-side "
                         "id-keyed client store, per-id synthetic data — "
                         "nothing O(K) in the round loop")
    obs_cli.add_args(ap)   # --trace out.jsonl / --metrics out.json
    args = ap.parse_args(argv)
    with obs_cli.session(args):
        return run(args)


def run(args):
    K = 20 if args.fast else args.clients
    rounds = 3 if args.fast else args.rounds
    fraction = (args.participation if args.fraction is None
                else args.fraction)
    use_cohort = (args.cohort or K >= 10000) and not args.dense

    hp = DSFLConfig(rounds=rounds, local_epochs=1, distill_epochs=1,
                    batch_size=20, open_batch=200, aggregation="era")
    algo = DSFLAlgorithm(apply_tiny_mlp, hp)

    # a heterogeneous mobile fleet: lognormal compute and uplink, 10x
    # downlink, availability in [0.6, 1.0]; stragglers past the deadline are
    # admitted into the NEXT round with staleness-decayed weight
    pop = ClientPopulation.lognormal(seed=0, K=K, compute_median=5.0,
                                     compute_sigma=0.8, uplink_median=2e4,
                                     uplink_sigma=1.0,
                                     availability=(0.6, 1.0))
    sched = SyncScheduler(pop, fraction=fraction, deadline=args.deadline,
                          straggler="admit", sampler="available")
    chunk = max(1, min(args.chunk, rounds))

    if use_cohort:
        prov = SyntheticProvider(seed=0, n_clients=K, n_per_client=20,
                                 n_open=200, n_test=300)
        eng = FedEngine(algo, make_eval_fn(apply_tiny_mlp, prov.x_test,
                                           prov.y_test))
        rng0 = jax.random.PRNGKey(hp.seed)
        store = ClientStore(
            lambda ids: algo.init_cohort(rng0, init_tiny_mlp, ids, K))
        runner = CohortRunner(engine=eng, scheduler=sched, provider=prov,
                              store=store, seed=0)
        runner.run(algo.init_server(rng0, init_tiny_mlp), rounds=rounds,
                   chunk_rounds=chunk, log_every=chunk)
        mode = (f"cohort-resident rounds: <= {sched.active_budget} of {K} "
                f"clients resident per round")
    else:
        task = build_image_task(seed=0, K=K, n_private=20 * K, n_open=200,
                                n_test=300, distribution="non_iid")
        eng = FedEngine(algo, make_eval_fn(apply_tiny_mlp, task.x_test,
                                           task.y_test))
        runner = SimRunner(eng, sched, seed=0)
        state = eng.init(init_tiny_mlp, task)
        # eval forces a host sync, so it rides the chunk cadence: log_every
        # == chunk keeps each scan segment fully fused
        runner.run(state, task, rounds=rounds, chunk_rounds=chunk,
                   log_every=chunk,
                   active_budget=None if args.dense else "auto")
        budget = sched.active_budget
        mode = ("dense masked rounds" if args.dense or budget >= K else
                f"sparse rounds: {budget}/{K} client lanes computed")

    print(f"\n{K} clients, {fraction:.2%} participation/round, "
          f"deadline {args.deadline:.0f}s, {mode}")
    for rec in runner.history:
        acc = (f"acc {rec['test_acc']:.3f}" if "test_acc" in rec
               else "acc   ----")   # evals land at chunk boundaries
        resident = (f"  resident {fmt_bytes(rec['resident_bytes'])}"
                    if "resident_bytes" in rec else "")
        print(f"round {rec['round']:3d}  vt {rec['t_cum']:9.1f}s  "
              f"{acc}  "
              f"{rec['participants']:4d} clients "
              f"({rec['dropped']} late, "
              f"stale {rec['mean_staleness']:.2f})  "
              f"cum {fmt_bytes(rec['cum_bytes'])}{resident}")
    if use_cohort:
        print(f"client state resident on host: "
              f"{fmt_bytes(runner.resident_bytes())} "
              f"({len(runner.store)} of {K} clients ever touched); "
              f"peak device slab {fmt_bytes(runner.peak_slab_bytes)}")
    t = runner.history.series("t_cum")
    ok = all(b > a for a, b in zip(t, t[1:])) and len(t) == rounds
    print("OK" if ok else "BROKEN CLOCK")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
