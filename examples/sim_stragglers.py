"""DS-FL on a simulated 100-device mobile fleet: 10% participation per
round, lognormal link rates, a straggler deadline — accuracy plotted against
*virtual wallclock* and measured cumulative bytes (the paper's Figs. 5-8
axes), all through the unchanged `FedEngine` round:

    pop    = ClientPopulation.lognormal(seed, K=100)
    sched  = SyncScheduler(pop, fraction=0.1, deadline=20.0, straggler="admit")
    eng    = FedEngine(algo, eval_fn)
    runner = SimRunner(eng, sched)
    state  = runner.run(eng.init(init, task), task, chunk_rounds=4)

``--chunk`` drives the *fused* sim path: sync participation is planned a
whole chunk ahead, and the chunk runs as one compiled `lax.scan` inside the
engine (`FedEngine.run(chunk_rounds=k, ctx_plan=...)`) — bitwise identical
to the per-round loop, without its one-dispatch-per-round host overhead.

At 10% participation the round is also *participation-sparse* by default
(``active_budget="auto"``): the engine computes only the scheduler's
budgeted ~``2 * ceil(0.1 * K)`` client lanes (admitted stragglers can ride
on top of the sampled cohort) instead of the full K-client stack — same
bits, ~K/m cheaper.  ``--dense`` forces the old full-stack masked round
for comparison.

  PYTHONPATH=src python examples/sim_stragglers.py          # ~2 min on CPU
  PYTHONPATH=src python examples/sim_stragglers.py --fast   # smoke (~30 s)
"""
import argparse
import sys

from repro.core.algorithms import DSFLAlgorithm
from repro.core.comm import fmt_bytes
from repro.core.engine import FedEngine, make_eval_fn
from repro.core.protocol import DSFLConfig
from repro.data.pipeline import build_image_task
from repro.models.smallnets import apply_tiny_mlp, init_tiny_mlp
from repro.sim import ClientPopulation, SimRunner, SyncScheduler


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--participation", type=float, default=0.1)
    ap.add_argument("--deadline", type=float, default=20.0)
    ap.add_argument("--chunk", type=int, default=4,
                    help="rounds fused per compiled lax.scan chunk "
                         "(1 = the per-round loop; bitwise identical)")
    ap.add_argument("--dense", action="store_true",
                    help="force the dense masked round (compute all K "
                         "clients) instead of the participation-sparse "
                         "plane; bitwise identical, ~K/m slower")
    args = ap.parse_args(argv)

    K = 20 if args.fast else args.clients
    rounds = 3 if args.fast else args.rounds
    task = build_image_task(seed=0, K=K, n_private=20 * K, n_open=200,
                            n_test=300, distribution="non_iid")

    hp = DSFLConfig(rounds=rounds, local_epochs=1, distill_epochs=1,
                    batch_size=20, open_batch=min(200, task.open_x.shape[0]),
                    aggregation="era")
    algo = DSFLAlgorithm(apply_tiny_mlp, hp)
    eng = FedEngine(algo, make_eval_fn(apply_tiny_mlp, task.x_test,
                                       task.y_test))

    # a heterogeneous mobile fleet: lognormal compute and uplink, 10x
    # downlink, availability in [0.6, 1.0]; stragglers past the deadline are
    # admitted into the NEXT round with staleness-decayed weight
    pop = ClientPopulation.lognormal(seed=0, K=K, compute_median=5.0,
                                     compute_sigma=0.8, uplink_median=2e4,
                                     uplink_sigma=1.0,
                                     availability=(0.6, 1.0))
    sched = SyncScheduler(pop, fraction=args.participation,
                          deadline=args.deadline, straggler="admit",
                          sampler="available")
    runner = SimRunner(eng, sched, seed=0)

    state = eng.init(lambda k: init_tiny_mlp(k), task)
    # eval forces a host sync, so it rides the chunk cadence: log_every ==
    # chunk keeps each scan segment fully fused (chunk snaps to log_every)
    chunk = max(1, min(args.chunk, rounds))
    runner.run(state, task, rounds=rounds, chunk_rounds=chunk,
               log_every=chunk,
               active_budget=None if args.dense else "auto")

    budget = sched.active_budget
    print(f"\n{K} clients, {args.participation:.0%} participation/round, "
          f"deadline {args.deadline:.0f}s, "
          + ("dense masked rounds" if args.dense or budget >= K else
         f"sparse rounds: {budget}/{K} client lanes computed"))
    for rec in runner.history:
        acc = (f"acc {rec['test_acc']:.3f}" if "test_acc" in rec
               else "acc   ----")   # evals land at chunk boundaries
        print(f"round {rec['round']:3d}  vt {rec['t_cum']:7.1f}s  "
              f"{acc}  "
              f"{rec['participants']:3d} clients "
              f"({rec['dropped']} late, "
              f"stale {rec['mean_staleness']:.2f})  "
              f"cum {fmt_bytes(rec['cum_bytes'])}")
    t = runner.history.series("t_cum")
    ok = all(b > a for a, b in zip(t, t[1:])) and len(t) == rounds
    print("OK" if ok else "BROKEN CLOCK")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
